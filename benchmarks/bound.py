"""Theorem 1 verification: the FedLDF↔FedAvg gap bound vs n and t.

CSV: n,K,A,B,asymptotic_gap  followed by  t,gap_bound rows for n=4.
Checks the paper's analytical claims: A<1 under the ξ₂ condition; the gap
shrinks monotonically in n; it vanishes at n=K.
"""
from __future__ import annotations

import sys


from repro.core.convergence import (BoundParams, asymptotic_gap,
                                    contraction_A, gap_bound, gap_curve,
                                    offset_B, xi2_max)

BASE = dict(beta=1.0, xi1=0.05, xi2=0.02, grad_bound=1.0, eta=0.05,
            num_layers=9, k=20)


def run(out=sys.stdout):
    print("n,K,A,B,asymptotic_gap", file=out)
    gaps = []
    for n in (1, 2, 4, 8, 12, 16, 20):
        p = BoundParams(n=n, **BASE)
        assert p.xi2 < xi2_max(p), "xi2 violates the convergence condition"
        a, b, g = contraction_A(p), offset_B(p), asymptotic_gap(p)
        gaps.append(g)
        print(f"{n},{p.k},{a:.6f},{b:.6f},{g:.6f}", file=out)
    assert all(x >= y - 1e-12 for x, y in zip(gaps, gaps[1:])), \
        "gap must shrink as n grows"
    assert gaps[-1] == 0.0, "n=K must close the gap (FedLDF -> FedAvg)"

    print("t,gap_bound_n4", file=out)
    curve = gap_curve(BoundParams(n=4, **BASE), rounds=50, gap0=0.5)
    for t, g in enumerate(curve):
        if t % 5 == 0:
            print(f"{t},{g:.6f}", file=out)
    return gaps


if __name__ == "__main__":
    run()
