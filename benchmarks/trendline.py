"""Perf trendline: diff a BENCH_ci.json against a windowed-median baseline.

    python benchmarks/trendline.py --prev p1/BENCH_ci.json \
        [--prev p2/BENCH_ci.json ...] --curr BENCH_ci.json \
        [--threshold 0.2] [--strict]

CI (ci.yml `bench-trend` job) fetches up to the last 5 same-branch
``BENCH_ci`` artifacts and runs this after every bench-smoke, so
rounds/sec and the ``[shard]`` speedup get a regression gate instead of
only a recorded trajectory. The baseline for each metric is the **median
across the previous runs** that report it (``--prev`` is repeatable,
window capped at :data:`WINDOW`): a single noisy runner in the history
can neither mask a real regression (one inflated previous run no longer
IS the baseline) nor fake one (one deflated run can't drag the baseline
down). Unreadable/missing ``--prev`` files are skipped individually; with
no usable history the diff is skipped cleanly.

The gate is **fail-soft** by default: regressions beyond the threshold
print GitHub ``::warning::`` annotations and the exit code stays 0 — CI
bench runners are noisy shared machines, so a hard gate would flake;
``--strict`` turns regressions into a non-zero exit for local use.

Only stdlib — runnable without PYTHONPATH or jax.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys

# windowed-median baseline: at most this many previous runs are consulted
# (newest last — callers pass them oldest→newest; extras are dropped from
# the OLD end)
WINDOW = 5

# metric path -> human label. Higher is better for every tracked metric
# (rates and speedups), so a regression is curr < (1 - threshold) * prev.
TRACKED = {
    ("kernel", "uplink_fused_speedup"): "[kernel] fused-uplink speedup "
                                        "vs unfused chain",
    ("engine", "host_rate"): "[engine] host-loop rounds/sec",
    ("engine", "scan_rate"): "[engine] scan-engine rounds/sec",
    ("engine", "fedlama_rate"): "[engine] fedlama (stateful) rounds/sec",
    ("engine", "telemetry_rate"): "[engine] scan + full telemetry "
                                  "rounds/sec",
    ("engine", "telemetry_ratio"): "[engine] telemetry-enabled/disabled "
                                   "rate ratio",
    ("engine", "speedup"): "[engine] scan-vs-host speedup",
    ("shard", "unsharded"): "[shard] unsharded rounds/sec",
    ("shard", "speedup"): "[shard] widest-mesh speedup",
    ("shard", "hier_rate"): "[shard] two-tier reduce rounds/sec",
}


def extract(results: dict) -> dict[str, float]:
    """Flatten the tracked metrics (plus per-mesh [shard] rates) out of a
    benchmarks/run.py --json dump. Missing sections are skipped — the
    comparison only covers metrics present in BOTH runs."""
    out: dict[str, float] = {}
    for (section, key), _ in TRACKED.items():
        sec = results.get(section)
        if not isinstance(sec, dict):
            continue   # e.g. pre-wire [kernel] artifacts stored a CSV list
        val = sec.get(key)
        if isinstance(val, (int, float)):
            out[f"{section}.{key}"] = float(val)
    for d, rate in ((results.get("shard") or {}).get("mesh") or {}).items():
        if isinstance(rate, (int, float)):
            out[f"shard.mesh.{d}"] = float(rate)
    model = (results.get("shard") or {}).get("model_mesh") or {}
    if isinstance(model.get("rate"), (int, float)):
        out["shard.model_mesh.rate"] = float(model["rate"])
    pop = (results.get("shard") or {}).get("population") or {}
    for key in ("rate", "flat_rate", "at_rest_shrink"):
        if isinstance(pop.get(key), (int, float)):
            out[f"shard.pop.{key}"] = float(pop[key])
    return out


def median_baseline(runs: list[dict[str, float]]) -> dict[str, float]:
    """Per-metric median over the last ``WINDOW`` runs that report it.

    A metric only needs to appear in ONE previous run to be tracked —
    ``statistics.median`` is taken over however many runs carry it, so a
    freshly added benchmark section starts getting gated as soon as one
    artifact records it."""
    window = runs[-WINDOW:]
    out: dict[str, float] = {}
    for name in {k for run in window for k in run}:
        vals = [run[name] for run in window if name in run]
        out[name] = float(statistics.median(vals))
    return out


def compare(prev: dict[str, float], curr: dict[str, float],
            threshold: float = 0.2) -> tuple[list[str], list[str]]:
    """Returns (regressions, report_lines). A metric regresses when it
    drops more than ``threshold`` relative to the baseline (for the
    windowed CI gate, ``prev`` is the :func:`median_baseline`)."""
    regressions, lines = [], []
    for name in sorted(set(prev) & set(curr)):
        p, c = prev[name], curr[name]
        if p <= 0:
            continue
        delta = (c - p) / p
        line = f"{name}: {p:.3f} -> {c:.3f} ({delta:+.1%})"
        lines.append(line)
        if delta < -threshold:
            regressions.append(line)
    for name in sorted(set(curr) - set(prev)):
        lines.append(f"{name}: (new) {curr[name]:.3f}")
    for name in sorted(set(prev) - set(curr)):
        lines.append(f"{name}: {prev[name]:.3f} -> (gone)")
    return regressions, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--prev", required=True, action="append",
                    help="a previous run's BENCH_ci.json (repeatable, "
                         "oldest first; baseline = per-metric median of "
                         f"the last {WINDOW}; unreadable files skipped)")
    ap.add_argument("--curr", required=True, help="this run's BENCH_ci.json")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative drop that counts as a regression")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on regression (default: warn only)")
    args = ap.parse_args(argv)

    prev_runs: list[dict[str, float]] = []
    for path in args.prev:
        try:
            with open(path) as f:
                prev_runs.append(extract(json.load(f)))
        except (OSError, json.JSONDecodeError) as e:
            # expired artifact / partial download — skip this one only
            print(f"trendline: skipping unreadable previous artifact "
                  f"{path} ({e})")
    if not prev_runs:
        # first run on a branch — nothing to diff against
        print("trendline: no usable previous artifact; skipping diff")
        return 0
    baseline = median_baseline(prev_runs)
    with open(args.curr) as f:
        curr = extract(json.load(f))

    regressions, lines = compare(baseline, curr, args.threshold)
    print(f"perf trendline (median of last {len(prev_runs[-WINDOW:])} "
          "run(s) -> curr):")
    for line in lines:
        print(f"  {line}")
    if not regressions:
        print(f"no regressions beyond {args.threshold:.0%}")
        return 0
    for line in regressions:
        print(f"::warning title=perf regression::{line}")
    print(f"{len(regressions)} metric(s) regressed more than "
          f"{args.threshold:.0%} vs the windowed-median baseline "
          f"({'failing' if args.strict else 'fail-soft: not failing'} "
          "the job; CI bench runners are noisy — treat as a flag to "
          "investigate, and compare BENCH_ci artifacts across a few runs)")
    return 1 if args.strict else 0


if __name__ == "__main__":
    sys.exit(main())
