"""Perf trendline: diff a BENCH_ci.json against the previous run's artifact.

    python benchmarks/trendline.py --prev prev/BENCH_ci.json \
        --curr BENCH_ci.json [--threshold 0.2] [--strict]

CI (ci.yml `bench-trend` job) fetches the previous push's ``BENCH_ci``
artifact and runs this after every bench-smoke, so rounds/sec and the
``[shard]`` speedup get a regression gate instead of only a recorded
trajectory (the ROADMAP "CI perf trendline" item). The gate is
**fail-soft** by default: regressions beyond the threshold print GitHub
``::warning::`` annotations and the exit code stays 0 — CI bench runners
are noisy shared machines, so a hard gate would flake; ``--strict`` turns
regressions into a non-zero exit for local use.

Only stdlib — runnable without PYTHONPATH or jax.
"""
from __future__ import annotations

import argparse
import json
import sys

# metric path -> human label. Higher is better for every tracked metric
# (rates and speedups), so a regression is curr < (1 - threshold) * prev.
TRACKED = {
    ("engine", "host_rate"): "[engine] host-loop rounds/sec",
    ("engine", "scan_rate"): "[engine] scan-engine rounds/sec",
    ("engine", "speedup"): "[engine] scan-vs-host speedup",
    ("shard", "unsharded"): "[shard] unsharded rounds/sec",
    ("shard", "speedup"): "[shard] widest-mesh speedup",
}


def extract(results: dict) -> dict[str, float]:
    """Flatten the tracked metrics (plus per-mesh [shard] rates) out of a
    benchmarks/run.py --json dump. Missing sections are skipped — the
    comparison only covers metrics present in BOTH runs."""
    out: dict[str, float] = {}
    for (section, key), _ in TRACKED.items():
        val = (results.get(section) or {}).get(key)
        if isinstance(val, (int, float)):
            out[f"{section}.{key}"] = float(val)
    for d, rate in ((results.get("shard") or {}).get("mesh") or {}).items():
        if isinstance(rate, (int, float)):
            out[f"shard.mesh.{d}"] = float(rate)
    model = (results.get("shard") or {}).get("model_mesh") or {}
    if isinstance(model.get("rate"), (int, float)):
        out["shard.model_mesh.rate"] = float(model["rate"])
    return out


def compare(prev: dict[str, float], curr: dict[str, float],
            threshold: float = 0.2) -> tuple[list[str], list[str]]:
    """Returns (regressions, report_lines). A metric regresses when it
    drops more than ``threshold`` relative to the previous run."""
    regressions, lines = [], []
    for name in sorted(set(prev) & set(curr)):
        p, c = prev[name], curr[name]
        if p <= 0:
            continue
        delta = (c - p) / p
        line = f"{name}: {p:.3f} -> {c:.3f} ({delta:+.1%})"
        lines.append(line)
        if delta < -threshold:
            regressions.append(line)
    for name in sorted(set(curr) - set(prev)):
        lines.append(f"{name}: (new) {curr[name]:.3f}")
    for name in sorted(set(prev) - set(curr)):
        lines.append(f"{name}: {prev[name]:.3f} -> (gone)")
    return regressions, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--prev", required=True,
                    help="previous run's BENCH_ci.json")
    ap.add_argument("--curr", required=True, help="this run's BENCH_ci.json")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative drop that counts as a regression")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on regression (default: warn only)")
    args = ap.parse_args(argv)

    try:
        with open(args.prev) as f:
            prev = extract(json.load(f))
    except (OSError, json.JSONDecodeError) as e:
        # first run on a branch / expired artifact — nothing to diff against
        print(f"trendline: no usable previous artifact ({e}); skipping diff")
        return 0
    with open(args.curr) as f:
        curr = extract(json.load(f))

    regressions, lines = compare(prev, curr, args.threshold)
    print("perf trendline (prev -> curr):")
    for line in lines:
        print(f"  {line}")
    if not regressions:
        print(f"no regressions beyond {args.threshold:.0%}")
        return 0
    for line in regressions:
        print(f"::warning title=perf regression::{line}")
    print(f"{len(regressions)} metric(s) regressed more than "
          f"{args.threshold:.0%} vs the previous run "
          f"({'failing' if args.strict else 'fail-soft: not failing'} "
          "the job; CI bench runners are noisy — treat as a flag to "
          "investigate, and compare BENCH_ci artifacts across a few runs)")
    return 1 if args.strict else 0


if __name__ == "__main__":
    sys.exit(main())
