"""Generate the §Dry-run / §Roofline markdown tables from artifacts.

    PYTHONPATH=src python -m benchmarks.make_report [dir] > experiments/roofline.md
"""
from __future__ import annotations

import glob
import json
import os
import sys
from collections import defaultdict


def load(artifact_dir: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        r["variant"] = (r["mesh"].split("__", 1)[1]
                        if "__" in r["mesh"] else "baseline")
        r["mesh_base"] = r["mesh"].split("__", 1)[0]
        rows.append(r)
    return rows


def fmt_bytes(x: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def dryrun_table(rows, out):
    print("\n### §Dry-run — per-device memory & collective mix "
          "(baseline, both meshes)\n", file=out)
    print("| arch | shape | mesh | args/dev | temp/dev | coll/dev | "
          "top collective |", file=out)
    print("|---|---|---|---|---|---|---|", file=out)
    for r in rows:
        if r["variant"] != "baseline":
            continue
        mem = r.get("memory_per_device") or {}
        coll = r.get("collective_by_type", {})
        top = max(coll, key=coll.get) if any(coll.values()) else "-"
        print(f"| {r['arch']} | {r['shape']} | {r['mesh_base']} | "
              f"{fmt_bytes(mem.get('argument_size_in_bytes', 0))} | "
              f"{fmt_bytes(mem.get('temp_size_in_bytes', 0))} | "
              f"{fmt_bytes(r['collective_per_device'])} | {top} |", file=out)


def recommendation(r) -> str:
    """One sentence per pair: what would move the dominant term down
    (grounded in the measured §Perf iterations — EXPERIMENTS.md)."""
    arch, shape, dom = r["arch"], r["shape"], r["dominant"]
    ssm = arch.startswith(("mamba", "hymba"))
    moe = "moe" in arch or "maverick" in arch
    heads_pad = arch in ("hymba-1.5b", "qwen2-7b")
    if shape == "train_4k":
        if dom == "memory":
            fix = "remat+flash_tune (measured −92 % memory on pair A)"
            if moe:
                fix += " then expert_parallel/moe_full (−69 % collective)"
            elif heads_pad:
                fix += " + head_pad (25/28H replicate over model=16)"
            else:
                fix += " then megatron TP (−79 % collective)"
            return fix
        return "megatron column/row TP removes per-matmul partial-sum ARs"
    if shape == "prefill_32k":
        if ssm:
            return ("ssm_proj column/row-parallel projections (measured "
                    "−67 % collective, −63 % memory on pair B); fused "
                    "Pallas SSD next")
        return ("kernels/flash_attention.py keeps probs/carries in VMEM "
                "(XLA lowering leaves them in HBM); megatron TP for the ARs")
    # decode shapes
    if dom in ("memory", "collective"):
        if ssm and shape == "long_500k":
            return "already communication-free recurrent state; at roofline"
        return ("cache_batch layout — B→data, hd→model (measured −40 % "
                "memory / −36 % collective on pair D); weights stay FSDP "
                "(megatron refuted: +162 % memory at decode)")
    return "compute-bound: at roofline for this shape"


def roofline_table(rows, out, mesh="16x16"):
    print(f"\n### §Roofline — three terms per (arch × shape), {mesh}, "
          "baseline\n", file=out)
    print("| arch | shape | compute | memory | collective | dominant | "
          "useful FLOPs ratio | what moves the dominant term |", file=out)
    print("|---|---|---|---|---|---|---|---|", file=out)
    for r in rows:
        if r["variant"] != "baseline" or r["mesh_base"] != mesh:
            continue
        print(f"| {r['arch']} | {r['shape']} | "
              f"{r['t_compute_s']*1e3:.1f}ms | {r['t_memory_s']*1e3:.1f}ms | "
              f"{r['t_collective_s']*1e3:.1f}ms | **{r['dominant']}** | "
              f"{r['useful_flops_ratio']:.3f} | {recommendation(r)} |",
              file=out)


def telemetry_table(artifact_dir, out):
    """§Telemetry — summarise any FL round ledgers (*.jsonl) found next to
    the dry-run artifacts (e.g. the TELEMETRY_ci.jsonl the bench-smoke CI
    job uploads). Reads the ledger records instead of re-deriving metrics;
    fail-soft when no ledgers (or no repro on the path) are present."""
    paths = sorted(glob.glob(os.path.join(artifact_dir, "*.jsonl")))
    if not paths:
        return
    try:
        from repro.telemetry import read_ledger, split_runs
    except ImportError:
        print("\n(telemetry ledgers present but repro not importable — "
              "run with PYTHONPATH=src)", file=out)
        return
    print("\n### §Telemetry — FL round ledgers\n", file=out)
    print("| run | algo | driver | rounds | final loss | uplink | "
          "savings | wall/round |", file=out)
    print("|---|---|---|---|---|---|---|---|", file=out)
    for path in paths:
        for seg in split_runs(read_ledger(path)):
            meta, rounds_rec = seg["meta"] or {}, seg["rounds"]
            if not rounds_rec:
                continue
            up = rounds_rec[-1]["uplink_cum_bytes"]
            base = sum(r["comm"]["fedavg_uplink"] for r in rounds_rec)
            walls = [r["wall_s"] for r in rounds_rec
                     if r.get("wall_s") is not None]
            wall = (f"{sorted(walls)[len(walls) // 2] * 1e3:.1f}ms"
                    if walls else "-")
            print(f"| {meta.get('run_id') or os.path.basename(path)} | "
                  f"{meta.get('algo', '?')} | {meta.get('driver', '?')} | "
                  f"{len(rounds_rec)} | {rounds_rec[-1]['loss']:.4f} | "
                  f"{fmt_bytes(up)} | {1 - up / base:.3f} | {wall} |",
                  file=out)


def perf_table(rows, out):
    variants = [r for r in rows if r["variant"] != "baseline"]
    if not variants:
        return
    base = {(r["arch"], r["shape"], r["mesh_base"]): r for r in rows
            if r["variant"] == "baseline"}
    print("\n### §Perf — variant deltas vs baseline\n", file=out)
    print("| arch | shape | variant | Δcompute | Δmemory | Δcollective | "
          "dominant before→after |", file=out)
    print("|---|---|---|---|---|---|---|", file=out)
    for r in variants:
        b = base.get((r["arch"], r["shape"], r["mesh_base"]))
        if not b:
            continue

        def d(key):
            if b[key] == 0:
                return "n/a"
            return f"{(r[key]/b[key]-1)*100:+.1f}%"

        print(f"| {r['arch']} | {r['shape']} | {r['variant']} | "
              f"{d('t_compute_s')} | {d('t_memory_s')} | "
              f"{d('t_collective_s')} | {b['dominant']}→{r['dominant']} |",
              file=out)


def main():
    artifact_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    rows = load(artifact_dir)
    out = sys.stdout
    n_base = defaultdict(set)
    for r in rows:
        if r["variant"] == "baseline":
            n_base[r["mesh_base"]].add((r["arch"], r["shape"]))
    print(f"artifacts: {len(rows)} "
          f"({ {m: len(v) for m, v in n_base.items()} } baseline combos)",
          file=out)
    dryrun_table(rows, out)
    roofline_table(rows, out, "16x16")
    roofline_table(rows, out, "2x16x16")
    perf_table(rows, out)
    telemetry_table(artifact_dir, out)


if __name__ == "__main__":
    main()
