"""Client-axis sharding benchmark: rounds/sec vs device-mesh size.

    PYTHONPATH=src python -m benchmarks.shard_engine_bench
        [--devices 8] [--rounds N] [--reps R] [--clients N] [--json PATH]

Measures :func:`repro.federated.run_training_scan` on a client-heavy FedLDF
workload (N=K=64 clients by default) with the stacked client axis sharded
over a 'clients' mesh of 1, 2, 4, ... devices, against the unsharded
``mesh=None`` single-device engine. On CPU the devices are forced virtual
ones (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — the same
flag CI uses — so the scaling path is measurable in any container; each
virtual device executes on its own thread, so the ceiling is the physical
core count, not 8.

The workload uses ``local_steps=2``: after the first local step every
client's weights have diverged, so the remaining local-training matmuls are
per-client batched ops that XLA cannot collapse into one device-wide GEMM —
exactly the regime where the client axis is the scaling dimension (and the
regime of real FL, where clients run many local steps). With
``local_steps=1`` a single device can fuse the whole cohort's forward pass
into one multithreaded GEMM and sharding has nothing left to win on CPU.

When the current process lacks the requested device count (e.g. invoked
from benchmarks/run.py after JAX already initialised the single real CPU
device), the benchmark re-executes itself in a subprocess with XLA_FLAGS
set, streams its output, and returns the parsed results.

Also measures one 2-D ('clients', 'model') mesh point — the FSDP
configuration where params (and the EF residual store) live 1/M per device
— reporting both rounds/sec and the at-rest per-device param bytes, and
re-checks sharded-vs-unsharded trajectory equivalence on a fixed seed
(fp32 tolerance — reduction order differs across mesh sizes) including the
2-D mesh, the hierarchical two-tier reduce (``FLConfig(agg_group_size=...)``
at group sizes 2 and 4), and the sample-sharded placement
(``shard_samples=True`` vs replicated placement of the same affinity
layout, grouped cohort in both).

**Population scale** (``population_run`` / ``--pop-clients``): an
N=1e6-client, K=4096-cohort synthetic round on the widest mesh with
sample-axis sharding + client→device affinity and the hierarchical
aggregation tier, reporting per-round wall-clock, per-tier bytes/host
(intra-group vs cross-group — the flat reduce funnels all D−1 payloads
through one root, the two-tier reduce caps any host at 2·(G−1) ring
payloads), and at-rest dataset bytes/device (~1/D shrink vs replicated
placement, asserted).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from benchmarks.round_engine_bench import EQUIV_TOL  # single source

# paper-motivated, client-heavy: full participation of a 64-client cohort
D_IN, HIDDEN, N_CLASSES = 3072, 64, 10
LOCAL_STEPS = 2


def _mlp_params(key):
    import jax
    import jax.numpy as jnp
    ks = jax.random.split(key, 2)
    return {"l1": {"w": jax.random.normal(ks[0], (D_IN, HIDDEN)) * 0.02,
                   "b": jnp.zeros((HIDDEN,))},
            "head": {"w": jax.random.normal(ks[1], (HIDDEN, N_CLASSES)) * 0.1,
                     "b": jnp.zeros((N_CLASSES,))}}


def _mlp_loss(params, batch):
    import jax
    import jax.numpy as jnp
    x = batch["images"].reshape(batch["images"].shape[0], -1)
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    logits = h @ params["head"]["w"] + params["head"]["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, batch["labels"][:, None],
                                axis=-1).mean()


def _make_task(num_clients: int, batch: int, seed: int = 0):
    import jax
    from repro.data import (ClientShards, FederatedData, iid_partition,
                            make_image_dataset)
    from repro.federated import FLConfig
    train, _ = make_image_dataset(num_train=num_clients * 50, num_test=16,
                                  seed=1)
    parts = iid_partition(train.ys, num_clients, seed=seed)
    shards = ClientShards.from_federated(
        FederatedData(train.xs, train.ys, parts))
    params = _mlp_params(jax.random.PRNGKey(seed))

    def flcfg(mesh, **kw):
        return FLConfig(algo="fedldf", num_clients=num_clients,
                        clients_per_round=num_clients, top_n=4,
                        local_steps=LOCAL_STEPS, batch_per_client=batch,
                        mesh=mesh, **kw)

    return params, _mlp_loss, shards, flcfg


def _best_rates(fns: list, rounds: int, reps: int) -> list[float]:
    """Best-of-``reps`` rounds/sec for every candidate, measured
    *interleaved* (one rep of each per sweep) so ambient-load drift on a
    shared box biases all candidates equally instead of whichever ran
    last; first call per candidate warms the jit cache outside timing."""
    for fn in fns:
        fn()
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            best[i] = min(best[i], time.perf_counter() - t0)
    return [rounds / b for b in best]


def _mesh_sizes(limit: int) -> list[int]:
    sizes, d = [], 1
    while d <= limit:
        sizes.append(d)
        d *= 2
    return sizes


def run_local(devices: int = 8, rounds: int = 30, reps: int = 5,
              clients: int = 64, batch: int = 16,
              pop_clients: int = 1_000_000, pop_cohort: int = 4096,
              pop_rounds: int = 3, out=sys.stdout) -> dict:
    """Run in-process (requires >= ``devices`` JAX devices)."""
    import jax
    from repro.federated import run_training_scan
    from repro.launch.mesh import make_client_mesh

    params, loss, shards, flcfg = _make_task(clients, batch)
    print(f"clients={clients} (full participation) B={batch} "
          f"local_steps={LOCAL_STEPS} rounds={rounds} "
          f"devices={len(jax.devices())} backend={jax.default_backend()}",
          file=out)

    results = {"clients": clients, "batch": batch, "rounds": rounds,
               "devices": len(jax.devices()), "mesh": {}}
    sizes = _mesh_sizes(min(devices, len(jax.devices())))

    def runner(mesh, **kw):
        return lambda: run_training_scan(params, loss, shards,
                                         flcfg(mesh, **kw),
                                         rounds=rounds, seed=0)

    rates = _best_rates(
        [runner(None)] + [runner(make_client_mesh(d)) for d in sizes],
        rounds, reps)
    rate_un, mesh_rates = rates[0], rates[1:]
    results["unsharded"] = rate_un
    print(f"mesh=None (single-device engine): {rate_un:8.1f} rounds/s",
          file=out)
    for d, rate in zip(sizes, mesh_rates):
        results["mesh"][str(d)] = rate
        print(f"mesh={d} sharded engine         : {rate:8.1f} rounds/s "
              f"({rate / rate_un:.2f}x vs unsharded)", file=out)

    # headline: widest mesh vs the FASTER single-device variant (mesh=1 runs
    # the same shard_map machinery on one device; mesh=None is the plain
    # engine — comparing against the better of the two keeps us honest)
    widest = max(int(s) for s in results["mesh"])
    base = max(rate_un, results["mesh"]["1"])
    results["speedup"] = results["mesh"][str(widest)] / base
    print(f"speedup: {results['speedup']:.2f}x at {widest} devices vs best "
          f"1-device engine (ceiling = physical cores, "
          f"os.cpu_count()={os.cpu_count()})", file=out)

    # 2-D ('clients', 'model') mesh: the FSDP point — same round math, but
    # params (and the EF store, when on) live 1/M per device. Rate is
    # expected at-or-below the pure clients-split (training all-gathers the
    # model transiently); the per-device at-rest bytes are the win.
    total = min(devices, len(jax.devices()))
    model = 2
    # skip (don't crash) when the 2-D factorisation doesn't fit: model must
    # divide the device count and K (= clients, full participation) must
    # divide the resulting clients axis
    if total % model == 0 and clients % (total // model) == 0:
        mesh2d = make_client_mesh(total, model=model)
        results["model_mesh"] = {
            "model": model, "clients_axis": total // model,
            "rate": _best_rates([runner(mesh2d)], rounds, reps)[0]}
        p2d, _ = run_training_scan(params, loss, shards, flcfg(mesh2d),
                                   rounds=1, seed=0)
        dev_b = sum(x.addressable_shards[0].data.nbytes
                    for x in jax.tree.leaves(p2d))
        tot_b = sum(x.nbytes for x in jax.tree.leaves(p2d))
        results["model_mesh"]["param_bytes_per_device"] = dev_b
        results["model_mesh"]["param_bytes_total"] = tot_b
        print(f"mesh=({total // model}x{model}) clients x model   : "
              f"{results['model_mesh']['rate']:8.1f} rounds/s; at-rest "
              f"param bytes/device {dev_b} vs {tot_b} replicated "
              f"({dev_b / tot_b:.2f}x)", file=out)

    # hierarchical two-tier reduce at the widest mesh (group-local psum +
    # group-leader ppermute ring; FLConfig(agg_group_size=...)). On forced
    # CPU devices the rate should track the flat psum — the win the tier
    # buys (per-HOST cross-group traffic capped at O(G) instead of the
    # root's O(D)) is reported by the population run's byte split below.
    if widest > 1:
        gs = max(1, widest // 4)
        wide_mesh = make_client_mesh(widest)
        results["hier_rate"] = _best_rates(
            [runner(wide_mesh, agg_group_size=gs)], rounds, reps)[0]
        results["hier"] = {"group_size": gs, "devices": widest,
                           "rate": results["hier_rate"]}
        print(f"mesh={widest} two-tier (group={gs})  : "
              f"{results['hier_rate']:8.1f} rounds/s "
              f"({results['hier_rate'] / results['mesh'][str(widest)]:.2f}x "
              "vs flat psum)", file=out)

    results["equiv_max_diff"] = equivalence_check(out=out)
    results["equiv_ok"] = results["equiv_max_diff"] < EQUIV_TOL

    if pop_clients:
        results["population"] = population_run(
            devices=devices, clients=pop_clients, cohort=pop_cohort,
            rounds=pop_rounds, out=out)
    return results


def equivalence_check(rounds: int = 3, out=sys.stdout) -> float:
    """Sharded (every power-of-2 mesh) vs unsharded trajectories, fixed
    seed. Fp32 tolerance: cross-device psum changes fp reduction order.
    Also pins the hierarchical two-tier reduce (group sizes 2/4 at the
    widest mesh) against the same unsharded reference, and the
    sample-sharded placement against replicated placement of the same
    affinity layout (grouped cohort in both — same participants, so the
    trajectories must agree bit-for-bit up to fp32 gather order)."""
    import jax
    import jax.numpy as jnp
    from repro.federated import run_training_scan
    from repro.launch.mesh import make_client_mesh

    def tree_diff(a, b):
        return max(float(jnp.abs(x - y).max()) for x, y in
                   zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    params, loss, shards, flcfg = _make_task(16, 8)
    params_ref, _ = run_training_scan(params, loss, shards, flcfg(None),
                                      rounds=rounds, seed=0)
    worst = 0.0
    ndev = len(jax.devices())
    meshes = [(d, 1, 0) for d in _mesh_sizes(ndev)]
    # 2-D ('clients', 'model') FSDP point (K=16 clients above)
    if ndev % 2 == 0 and 16 % (ndev // 2) == 0:
        meshes.append((ndev, 2, 0))
    # hierarchical two-tier reduce at the widest mesh
    meshes.extend((ndev, 1, gs) for gs in (1, 2, 4)
                  if gs < ndev and ndev % gs == 0)
    for d, model, gs in meshes:
        ps, _ = run_training_scan(
            params, loss, shards,
            flcfg(make_client_mesh(d, model=model), agg_group_size=gs),
            rounds=rounds, seed=0)
        diff = tree_diff(params_ref, ps)
        worst = max(worst, diff)
        status = "OK" if diff < EQUIV_TOL else "FAIL"
        label = f"{d}" if model == 1 else f"{d // model}x{model}"
        if gs:
            label += f" group={gs}"
        print(f"equivalence mesh={label}: max|sharded-unsharded| = "
              f"{diff:.2e}  [{status}]", file=out)

    # sample-axis sharding: sharded vs replicated placement of the SAME
    # affinity layout (the drivers draw the cohort per group for both, so
    # the participant trajectory is identical — only data placement moves)
    if ndev > 1 and 16 % ndev == 0:
        mesh = make_client_mesh(ndev)
        aff = shards.with_affinity(ndev)
        p_rep, _ = run_training_scan(params, loss, aff.place(mesh),
                                     flcfg(mesh), rounds=rounds, seed=0)
        p_shd, _ = run_training_scan(params, loss, aff,
                                     flcfg(mesh, shard_samples=True),
                                     rounds=rounds, seed=0)
        diff = tree_diff(p_rep, p_shd)
        worst = max(worst, diff)
        status = "OK" if diff < EQUIV_TOL else "FAIL"
        print(f"equivalence mesh={ndev} sample-sharded vs replicated "
              f"placement: max diff = {diff:.2e}  [{status}]", file=out)
    return worst


def population_run(devices: int = 8, clients: int = 1_000_000,
                   cohort: int = 4096, rounds: int = 3,
                   out=sys.stdout) -> dict:
    """Population-scale synthetic round: N≈1e6 clients, K≈4096 cohort.

    One sample per client (16 features), tiny MLP — the point is the
    *round machinery* at population N, not the model: vectorized shard
    construction, per-group cohort draw, sample-sharded placement with
    client→device affinity, device-local gather, and the two-tier reduce.
    Reports per-round wall-clock (flat vs hierarchical reduce), at-rest
    dataset bytes/device (~1/D shrink vs replicated placement — enforced),
    and the static per-tier aggregation-traffic split per round.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import agg_tier_bytes
    from repro.data import ClientShards, FederatedData
    from repro.federated import FLConfig, run_training_scan
    from repro.launch.mesh import make_client_mesh

    d = min(devices, len(jax.devices()))
    clients -= clients % d          # N % D (affinity groups, FLConfig)
    cohort -= cohort % d            # K % G (per-group cohort draw)
    d_in, hidden = 16, 8
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((clients, d_in), dtype=np.float32)
    ys = rng.integers(0, N_CLASSES, size=clients).astype(np.int32)
    print(f"[population] N={clients:,} clients, K={cohort:,} cohort, "
          f"{d} devices, {rounds} rounds", file=out)

    t0 = time.perf_counter()
    parts = list(np.arange(clients, dtype=np.int64).reshape(clients, 1))
    shards = ClientShards.from_federated(FederatedData(xs, ys, parts))
    build_s = time.perf_counter() - t0
    print(f"[population] ClientShards.from_federated: {build_s:.2f}s "
          f"(vectorized; the per-client loop was O(N*S))", file=out)

    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    params = {"l1": {"w": jax.random.normal(ks[0], (d_in, hidden)) * 0.1,
                     "b": jnp.zeros((hidden,))},
              "head": {"w": jax.random.normal(ks[1],
                                              (hidden, N_CLASSES)) * 0.1,
                       "b": jnp.zeros((N_CLASSES,))}}
    mesh = make_client_mesh(d)
    gs = max(1, d // 4)             # stand-in for devices-per-host

    # at-rest dataset footprint: replicated vs sample-sharded placement
    rep_b = shards.place(mesh).bytes_per_device()
    shd = shards.place(mesh, shard_samples=True)
    shd_b = shd.bytes_per_device()
    shrink = rep_b / shd_b
    print(f"[population] at-rest dataset bytes/device: {shd_b:,} sharded "
          f"vs {rep_b:,} replicated ({shrink:.1f}x shrink, D={d})",
          file=out)
    if d > 1 and shrink < 0.9 * d:
        raise RuntimeError(
            f"sample-axis sharding shrank at-rest bytes only {shrink:.2f}x "
            f"on {d} devices (expected ~{d}x)")

    def tr(**kw):
        cfg = FLConfig(algo="fedldf", num_clients=clients,
                       clients_per_round=cohort, top_n=2, local_steps=1,
                       batch_per_client=1, mesh=mesh, shard_samples=True,
                       **kw)
        return lambda: run_training_scan(params, _mlp_loss, shd, cfg,
                                         rounds=rounds, seed=0)

    flat_rate, hier_rate = _best_rates(
        [tr(), tr(agg_group_size=gs)], rounds, reps=2)
    print(f"[population] flat reduce      : {1 / flat_rate:8.3f} s/round",
          file=out)
    print(f"[population] two-tier (g={gs})  : {1 / hier_rate:8.3f} s/round",
          file=out)

    # static per-round aggregation-traffic split (payload = param bytes,
    # the Eq. 5 numerator tree riding the fused reduce)
    pbytes = float(sum(np.asarray(x).nbytes
                       for x in jax.tree.leaves(params)))
    tiers = {"flat": agg_tier_bytes(pbytes, d, 0),
             "hier": agg_tier_bytes(pbytes, d, gs)}
    for name, t in tiers.items():
        print(f"[population] {name} bytes/round: "
              f"intra={t['agg_intra_bytes']:,.0f} "
              f"cross={t['agg_cross_bytes']:,.0f} "
              f"busiest-host cross={t['agg_cross_bytes_per_host']:,.0f}",
              file=out)
    ratio = (tiers["hier"]["agg_cross_bytes_per_host"]
             / max(tiers["flat"]["agg_cross_bytes_per_host"], 1.0))
    print(f"[population] busiest-host cross-tier traffic: {ratio:.2f}x "
          "of flat (lower = the root is no longer the ceiling)", file=out)
    return {"clients": clients, "cohort": cohort, "devices": d,
            "group_size": gs, "rounds": rounds, "build_s": build_s,
            "rate": hier_rate, "flat_rate": flat_rate,
            "sec_per_round": 1.0 / hier_rate,
            "at_rest_bytes_per_device": shd_b,
            "at_rest_bytes_replicated": rep_b,
            "at_rest_shrink": shrink,
            "tier_bytes": tiers,
            "cross_host_ratio": ratio}


def run(devices: int = 8, rounds: int = 30, reps: int = 5,
        clients: int = 64, batch: int = 16,
        pop_clients: int = 1_000_000, pop_cohort: int = 4096,
        pop_rounds: int = 3, out=sys.stdout) -> dict:
    """Entry point for benchmarks/run.py: re-exec with forced devices when
    this process cannot see enough of them (JAX device count is fixed at
    first import; only a fresh process can change it)."""
    import jax
    if len(jax.devices()) >= devices:
        return run_local(devices, rounds, reps, clients, batch,
                         pop_clients=pop_clients, pop_cohort=pop_cohort,
                         pop_rounds=pop_rounds, out=out)

    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    flags = " ".join(f for f in flags.split()
                     if "--xla_force_host_platform_device_count" not in f)
    env["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_device_count="
                        f"{devices}").strip()
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    with_json = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "..", f".shard_bench_{os.getpid()}.json")
    cmd = [sys.executable, "-m", "benchmarks.shard_engine_bench",
           "--devices", str(devices), "--rounds", str(rounds),
           "--reps", str(reps), "--clients", str(clients),
           "--batch", str(batch), "--pop-clients", str(pop_clients),
           "--pop-cohort", str(pop_cohort),
           "--pop-rounds", str(pop_rounds), "--json", with_json]
    print(f"# re-exec with XLA_FLAGS={env['XLA_FLAGS']!r}", file=out)
    proc = subprocess.run(cmd, env=env, stdout=subprocess.PIPE,
                          stderr=subprocess.STDOUT, text=True,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    print(proc.stdout, end="", file=out)
    try:
        with open(with_json) as f:
            return json.load(f)
    except OSError:
        raise SystemExit(
            f"[shard] subprocess failed (exit {proc.returncode})")
    finally:
        try:
            os.remove(with_json)
        except OSError:
            pass


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--pop-clients", type=int, default=1_000_000,
                    help="population-scale run size (0 disables)")
    ap.add_argument("--pop-cohort", type=int, default=4096)
    ap.add_argument("--pop-rounds", type=int, default=3)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    results = run(devices=args.devices, rounds=args.rounds, reps=args.reps,
                  clients=args.clients, batch=args.batch,
                  pop_clients=args.pop_clients, pop_cohort=args.pop_cohort,
                  pop_rounds=args.pop_rounds)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
    return 0 if results.get("equiv_ok") else 1


if __name__ == "__main__":
    sys.exit(main())
