"""Paper Fig. 3 / Fig. 4 analogue: test error vs communication overhead.

Runs **every registered strategy** (FedLDF vs FedAvg / Random / HDFL /
FedADP / FedLP out of the box — ``register_strategy`` plugins are picked
up automatically) on the synthetic CIFAR-10-like task, IID and
Dirichlet(α=1), and emits CSV:

    fig,algo,round,uplink_mb,test_error

Every run records a telemetry ledger (one JSONL file per (fig, algo) in
``ledger_dir``), and both the CSV and :func:`summarize` are read back
**from the ledger** rather than re-derived from in-memory logs — the
comparison consumes the same artifact a monitoring/report pipeline would
(``repro.launch.monitor`` renders the same files).

Scale knobs default to a CI-friendly reduction of the paper's setup
(N=20 clients, K=10/round, n=2 — same n/K=0.2 ratio as the paper's
K=20/n=4); pass --paper-scale for the full §III-A configuration.
Equal-communication setting: FedADP's keep fraction and FedLP's layer
keep probability are both pinned to n/K, and FedLAMA's base aggregation
interval τ' is pinned to round(K/n) (steady-state uplink ≈ FedAvg/τ' ≈
n/K of FedAvg before any λτ' demotions), so the error-vs-bytes ordering
compares like against like.
"""
from __future__ import annotations

import argparse
import functools
import os
import sys
import tempfile

import jax
import jax.numpy as jnp

from repro.data import (FederatedData, dirichlet_partition, iid_partition,
                        make_image_dataset)
from repro.federated import (FedADPOptions, FedLAMAOptions, FedLPOptions,
                             FLConfig, TelemetryConfig, registered_algos,
                             run_training)
from repro.models import cnn
from repro.telemetry import read_ledger, split_runs


def run(paper_scale: bool = False, rounds: int = 40, seed: int = 0,
        out=sys.stdout, algos: tuple[str, ...] | None = None,
        ledger_dir: str | None = None):
    if paper_scale:
        cfg = cnn.VGGConfig()
        n_clients, k, n = 50, 20, 4
        n_train, n_test, batch, noise = 50_000, 10_000, 32, 2.5
    else:
        cfg = cnn.VGGConfig().reduced()
        n_clients, k, n = 20, 10, 2
        n_train, n_test, batch, noise = 3_000, 600, 16, 2.5

    if ledger_dir is None:
        ledger_dir = tempfile.mkdtemp(prefix="fl_comparison_ledgers_")
    os.makedirs(ledger_dir, exist_ok=True)

    # noise=2.5 keeps the task unsaturated over the benchmark horizon so the
    # error-vs-communication ordering (paper Figs. 3-4) is measurable.
    train, test = make_image_dataset(num_train=n_train, num_test=n_test,
                                     noise=noise, seed=seed)
    test_batch = {"images": jnp.asarray(test.xs),
                  "labels": jnp.asarray(test.ys)}
    loss_fn = functools.partial(lambda c, p, b: cnn.classify_loss(p, c, b),
                                cfg)
    eval_fn = jax.jit(lambda p: 1.0 - cnn.accuracy(p, cfg, test_batch))

    algos = tuple(algos) if algos is not None else registered_algos()
    # equal-comm pinning, spelled per strategy (see module docstring);
    # algos without an options class take algo_options=None
    algo_opts = {"fedadp": FedADPOptions(keep=n / k),
                 "fedlp": FedLPOptions(p=n / k),
                 "fedlama": FedLAMAOptions(tau=max(1, round(k / n)))}
    results = {}
    print("fig,algo,round,uplink_mb,test_error", file=out)
    for fig, splitter in (("fig3_iid", iid_partition),
                          ("fig4_noniid",
                           lambda y, nc, seed: dirichlet_partition(
                               y, nc, alpha=1.0, seed=seed))):
        parts = splitter(train.ys, n_clients, seed)
        data = FederatedData(train.xs, train.ys, parts)
        for algo in algos:
            ledger_path = os.path.join(ledger_dir, f"{fig}_{algo}.jsonl")
            # per-layer taps on, full (K, U) masks off: the comparison
            # reads bytes/error curves, not per-client membership
            fl = FLConfig(algo=algo, num_clients=n_clients,
                          clients_per_round=k, top_n=n, lr=0.08,
                          mode="vmap", batch_per_client=batch,
                          algo_options=algo_opts.get(algo),
                          telemetry=TelemetryConfig(
                              ledger_path=ledger_path,
                              run_id=f"{fig}/{algo}",
                              full_selection=False))
            params = cnn.init_params(jax.random.PRNGKey(seed), cfg)
            params, log = run_training(params, loss_fn, data, fl,
                                       rounds=rounds, eval_fn=eval_fn,
                                       eval_every=max(1, rounds // 10),
                                       seed=seed)
            # the CSV is read back from the ledger artifact, not the
            # in-memory log — same records monitor.py renders
            seg = split_runs(read_ledger(ledger_path))[-1]
            for ev in seg["evals"]:
                print(f"{fig},{algo},{ev['round']},"
                      f"{ev['uplink_cum_bytes']/1e6:.3f},"
                      f"{ev['test_error']:.4f}", file=out)
            results[(fig, algo)] = {"log": log, "ledger": ledger_path}
    return results


def summarize(results, out=sys.stdout):
    """Derived claims: savings ratio + error ordering (paper §III-B).

    Computed from the **ledger** round/eval records: total uplink is the
    last round record's cumulative bytes (never one round's profile scaled
    by the round count — strategies with non-constant per-round bytes
    (fedlama's round-0 full sync + interval-expiry schedule, fedlp's
    Bernoulli draws) would make that extrapolation wrong), and the
    FedAvg reference is the sum of each round's own ``fedavg_uplink``.
    """
    print("# summary: algo, final_err, total_uplink_mb, avg_round_mb, "
          "savings_vs_fedavg", file=out)
    algos = []
    for (_, algo) in results:          # registry order, deduped
        if algo not in algos:
            algos.append(algo)
    for fig in ("fig3_iid", "fig4_noniid"):
        for algo in algos:
            seg = split_runs(read_ledger(results[(fig, algo)]["ledger"]))[-1]
            rounds_rec, evals = seg["rounds"], seg["evals"]
            err = evals[-1]["test_error"]
            up = rounds_rec[-1]["uplink_cum_bytes"]
            # every round record carries its own uncompressed-FedAvg
            # reference bytes, so the savings column survives algo subsets
            # that omit fedavg itself (for fedavg, up == base -> 0.000)
            base = sum(r["comm"]["fedavg_uplink"] for r in rounds_rec)
            avg = up / max(len(rounds_rec), 1)
            line = (f"# {fig},{algo},{err:.4f},{up/1e6:.1f},{avg/1e6:.2f},"
                    f"{1 - up / base:.3f}")
            # mesh runs with the two-tier reduce also record the static
            # aggregation-traffic split per round (see core.comm)
            c = rounds_rec[-1]["comm"]
            if c.get("agg_tiers", 1) > 1:
                line += (f",agg2tier:intra={c['agg_intra_bytes']/1e6:.2f}MB"
                         f"/cross={c['agg_cross_bytes']/1e6:.2f}MB")
            print(line, file=out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--ledger-dir", default=None,
                    help="directory for per-run telemetry JSONL ledgers "
                         "(default: a fresh temp dir)")
    args = ap.parse_args()
    res = run(paper_scale=args.paper_scale, rounds=args.rounds,
              ledger_dir=args.ledger_dir)
    summarize(res)
