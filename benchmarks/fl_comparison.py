"""Paper Fig. 3 / Fig. 4 analogue: test error vs communication overhead.

Runs **every registered strategy** (FedLDF vs FedAvg / Random / HDFL /
FedADP / FedLP out of the box — ``register_strategy`` plugins are picked
up automatically) on the synthetic CIFAR-10-like task, IID and
Dirichlet(α=1), and emits CSV:

    fig,algo,round,uplink_mb,test_error

Scale knobs default to a CI-friendly reduction of the paper's setup
(N=20 clients, K=10/round, n=2 — same n/K=0.2 ratio as the paper's
K=20/n=4); pass --paper-scale for the full §III-A configuration.
Equal-communication setting: FedADP's keep fraction and FedLP's layer
keep probability are both pinned to n/K, and FedLAMA's base aggregation
interval τ' is pinned to round(K/n) (steady-state uplink ≈ FedAvg/τ' ≈
n/K of FedAvg before any λτ' demotions), so the error-vs-bytes ordering
compares like against like.
"""
from __future__ import annotations

import argparse
import functools
import sys

import jax
import jax.numpy as jnp

from repro.data import (FederatedData, dirichlet_partition, iid_partition,
                        make_image_dataset)
from repro.federated import FLConfig, registered_algos, run_training
from repro.models import cnn


def run(paper_scale: bool = False, rounds: int = 40, seed: int = 0,
        out=sys.stdout, algos: tuple[str, ...] | None = None):
    if paper_scale:
        cfg = cnn.VGGConfig()
        n_clients, k, n = 50, 20, 4
        n_train, n_test, batch, noise = 50_000, 10_000, 32, 2.5
    else:
        cfg = cnn.VGGConfig().reduced()
        n_clients, k, n = 20, 10, 2
        n_train, n_test, batch, noise = 3_000, 600, 16, 2.5

    # noise=2.5 keeps the task unsaturated over the benchmark horizon so the
    # error-vs-communication ordering (paper Figs. 3-4) is measurable.
    train, test = make_image_dataset(num_train=n_train, num_test=n_test,
                                     noise=noise, seed=seed)
    test_batch = {"images": jnp.asarray(test.xs),
                  "labels": jnp.asarray(test.ys)}
    loss_fn = functools.partial(lambda c, p, b: cnn.classify_loss(p, c, b),
                                cfg)
    eval_fn = jax.jit(lambda p: 1.0 - cnn.accuracy(p, cfg, test_batch))

    algos = tuple(algos) if algos is not None else registered_algos()
    results = {}
    print("fig,algo,round,uplink_mb,test_error", file=out)
    for fig, splitter in (("fig3_iid", iid_partition),
                          ("fig4_noniid",
                           lambda y, nc, seed: dirichlet_partition(
                               y, nc, alpha=1.0, seed=seed))):
        parts = splitter(train.ys, n_clients, seed)
        data = FederatedData(train.xs, train.ys, parts)
        for algo in algos:
            fl = FLConfig(algo=algo, num_clients=n_clients,
                          clients_per_round=k, top_n=n, lr=0.08,
                          mode="vmap", batch_per_client=batch,
                          fedadp_keep=n / k, fedlp_p=n / k,
                          fedlama_tau=max(1, round(k / n)))
            params = cnn.init_params(jax.random.PRNGKey(seed), cfg)
            params, log = run_training(params, loss_fn, data, fl,
                                       rounds=rounds, eval_fn=eval_fn,
                                       eval_every=max(1, rounds // 10),
                                       seed=seed)
            for (t, err, up) in log.test_errors:
                print(f"{fig},{algo},{t},{up/1e6:.3f},{err:.4f}", file=out)
            results[(fig, algo)] = log
    return results


def summarize(results, out=sys.stdout):
    """Derived claims: savings ratio + error ordering (paper §III-B).

    All columns are computed from the meter's *accumulated* byte totals,
    never from any single round's profile scaled by the round count —
    strategies with non-constant per-round bytes (fedlama's round-0 full
    sync + interval-expiry schedule, fedlp's Bernoulli draws) would make
    that extrapolation wrong. ``avg_round_mb`` is total/rounds for the
    same reason.
    """
    print("# summary: algo, final_err, total_uplink_mb, avg_round_mb, "
          "savings_vs_fedavg", file=out)
    algos = []
    for (_, algo) in results:          # registry order, deduped
        if algo not in algos:
            algos.append(algo)
    for fig in ("fig3_iid", "fig4_noniid"):
        for algo in algos:
            log = results[(fig, algo)]
            err = log.test_errors[-1][1]
            up = log.meter.uplink_bytes
            # every meter carries its own uncompressed-FedAvg reference
            # bytes, so the savings column survives algo subsets that
            # omit fedavg itself (for fedavg, up == base -> 0.000)
            base = log.meter.fedavg_uplink_bytes
            avg = up / max(log.meter.rounds, 1)
            print(f"# {fig},{algo},{err:.4f},{up/1e6:.1f},{avg/1e6:.2f},"
                  f"{1 - up / base:.3f}", file=out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--rounds", type=int, default=40)
    args = ap.parse_args()
    res = run(paper_scale=args.paper_scale, rounds=args.rounds)
    summarize(res)
