"""Ablation: the n/K trade-off (extends the paper's single n=4 point).

Theorem 1 predicts the FedLDF↔FedAvg gap shrinks monotonically in n and
vanishes at n=K. We sweep n at fixed K and report final test error, uplink,
and the analytic asymptotic gap bound side by side — the empirical errors
should (noisily) track the bound's ordering.

CSV: n,K,final_err,uplink_mb,savings,bound_gap
"""
from __future__ import annotations

import functools
import sys

import jax
import jax.numpy as jnp

from repro.core.convergence import BoundParams, asymptotic_gap
from repro.data import FederatedData, dirichlet_partition, make_image_dataset
from repro.federated import FLConfig, run_training
from repro.models import cnn


def run(rounds: int = 30, seed: int = 0, out=sys.stdout):
    cfg = cnn.VGGConfig().reduced()
    n_clients, k = 20, 10
    train, test = make_image_dataset(num_train=3_000, num_test=600,
                                     noise=2.5, seed=seed)
    parts = dirichlet_partition(train.ys, n_clients, alpha=1.0, seed=seed)
    data = FederatedData(train.xs, train.ys, parts)
    tb = {"images": jnp.asarray(test.xs), "labels": jnp.asarray(test.ys)}
    loss_fn = functools.partial(lambda c, p, b: cnn.classify_loss(p, c, b),
                                cfg)
    eval_fn = jax.jit(lambda p: 1.0 - cnn.accuracy(p, cfg, tb))

    print("n,K,final_err,uplink_mb,savings,bound_gap", file=out)
    results = []
    for n in (1, 2, 4, 6, 8, 10):
        fl = FLConfig(algo="fedldf", num_clients=n_clients,
                      clients_per_round=k, top_n=n, lr=0.08, mode="vmap",
                      batch_per_client=16)
        params = cnn.init_params(jax.random.PRNGKey(seed), cfg)
        params, log = run_training(params, loss_fn, data, fl, rounds=rounds,
                                   eval_fn=eval_fn, eval_every=rounds - 1,
                                   seed=seed)
        err = log.test_errors[-1][1]
        up = log.meter.uplink_bytes / 1e6
        bound = asymptotic_gap(BoundParams(
            beta=1.0, xi1=0.05, xi2=0.02, grad_bound=1.0, eta=0.05,
            num_layers=cfg.num_layers, n=n, k=k))
        results.append((n, err, bound))
        print(f"{n},{k},{err:.4f},{up:.2f},"
              f"{log.meter.savings_frac:.3f},{bound:.5f}", file=out)
    # structural check: the bound is monotone; print rank agreement
    bounds = [b for _, _, b in results]
    assert all(x >= y - 1e-12 for x, y in zip(bounds, bounds[1:]))
    return results


if __name__ == "__main__":
    run()
