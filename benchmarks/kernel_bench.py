"""Kernel microbenchmarks (CPU host): the FedLDF hot-spot ops.

CSV rows: name,us_per_call,derived — wall time of the jitted jnp fast path
(the deploy path on CPU) and of the Pallas kernel in interpret mode (the
correctness path; TPU timing is N/A in this container).
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from repro.kernels import aggregate as ka
from repro.kernels import divergence as kd
from repro.kernels import ref


def _time(fn, *args, iters=20) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(out=sys.stdout):
    key = jax.random.PRNGKey(0)
    r, c = 48, 1 << 18          # 48 layer-units × 262k params/unit
    a = jax.random.normal(key, (r, c))
    b = jax.random.normal(jax.random.PRNGKey(1), (r, c))
    w = jax.random.normal(jax.random.PRNGKey(2), (r,))

    jd = jax.jit(ref.sqdiff_rowsum)
    jm = jax.jit(ref.masked_accumulate)
    rows = [
        ("divergence_jnp_48x262k", _time(jd, a, b),
         f"{r*c*2*4/1e6:.0f}MB_traffic"),
        ("masked_acc_jnp_48x262k", _time(jm, a, a, w),
         f"{r*c*3*4/1e6:.0f}MB_traffic"),
        ("divergence_pallas_interp_4x4k",
         _time(lambda x, y: kd.sqdiff_rowsum(x, y, interpret=True),
               a[:4, :4096], b[:4, :4096], iters=3), "interpret_mode"),
        ("masked_acc_pallas_interp_4x4k",
         _time(lambda x, y, z: ka.masked_accumulate(x, y, z, interpret=True),
               a[:4, :4096], a[:4, :4096], w[:4], iters=3), "interpret_mode"),
    ]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", file=out)
    return rows


if __name__ == "__main__":
    run()
