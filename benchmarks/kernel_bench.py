"""Kernel microbenchmarks (CPU host): the FedLDF hot-spot ops.

CSV rows: name,us_per_call,derived — wall time of the jitted jnp fast path
(the deploy path on CPU) and of the Pallas kernel in interpret mode (the
correctness path; TPU timing is N/A in this container).

The ``uplink_*`` section is the packed-wire-format A/B: the fused
dequant + error-feedback + Eq. 5 accumulate op (one jitted call, no fp32
reconstruction ever materialized between stages) against the pre-wire
unfused chain (dequant, accumulate, and residual update as three separate
jitted ops over full fp32 buffers — the shape the quantized upload had
before ``core/wire``).  ``run()`` returns a dict so the trendline gate can
TRACK ``uplink_fused_speedup``; the CSV rows live under ``"rows"``.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from repro.kernels import aggregate as ka
from repro.kernels import divergence as kd
from repro.kernels import ref

# floor workload for the uplink A/B: K clients × R layer-units × C params
UPLINK_SHAPE = (8, 48, 1 << 16)


def _time(fn, *args, iters=20) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _uplink_ab(iters=10) -> dict:
    """Fused uplink op vs the unfused three-op chain on the floor shape.

    Returns μs per call for both paths, the speedup, and the uplink bytes
    each moves per round (packed int8 levels + fp32 scales vs the fp32
    buffers the unfused chain ships/materializes).
    """
    k, r, c = UPLINK_SHAPE
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    levels = jax.random.randint(ks[0], (k, r, c), -127, 128).astype(jnp.int8)
    scales = jax.random.uniform(ks[1], (k, r), minval=1e-4)
    w = jax.random.uniform(ks[2], (k, r))
    gate = (jax.random.uniform(ks[3], (k, r)) < 0.5).astype(jnp.float32)
    v = jax.random.normal(ks[4], (k, r, c))
    e_old = jax.random.normal(ks[5], (k, r, c))

    fused = jax.jit(ref.fused_uplink_ef)

    # the pre-wire chain: three XLA programs, fp32 recon materialized twice
    dequant = jax.jit(lambda l, s: l.astype(jnp.float32) * s[..., None])
    accum = jax.jit(lambda w_, r_: jnp.einsum("kr,krc->rc", w_, r_))
    resid = jax.jit(lambda g_, v_, r_, e_:
                    g_[..., None] * (v_ - r_) + (1 - g_[..., None]) * e_)

    def unfused(levels, scales, w, gate, v, e_old):
        recon = dequant(levels, scales)
        return accum(w, recon), resid(gate, v, recon, e_old)

    args = (levels, scales, w, gate, v, e_old)
    us_fused = _time(fused, *args, iters=iters)
    us_unfused = _time(unfused, *args, iters=iters)
    return {
        "shape": f"{k}x{r}x{c}",
        "uplink_fused_us": us_fused,
        "uplink_unfused_us": us_unfused,
        "uplink_fused_speedup": us_unfused / us_fused,
        # wire bytes per round: int8 levels + fp32 per-unit scales ...
        "uplink_packed_bytes": int(levels.nbytes + scales.nbytes),
        # ... vs the fp32 reconstruction the unfused chain works over
        "uplink_fp32_bytes": int(4 * levels.size + scales.nbytes),
    }


def run(out=sys.stdout) -> dict:
    key = jax.random.PRNGKey(0)
    r, c = 48, 1 << 18          # 48 layer-units × 262k params/unit
    a = jax.random.normal(key, (r, c))
    b = jax.random.normal(jax.random.PRNGKey(1), (r, c))
    w = jax.random.normal(jax.random.PRNGKey(2), (r,))

    jd = jax.jit(ref.sqdiff_rowsum)
    jm = jax.jit(ref.masked_accumulate)
    rows = [
        ("divergence_jnp_48x262k", _time(jd, a, b),
         f"{r*c*2*4/1e6:.0f}MB_traffic"),
        ("masked_acc_jnp_48x262k", _time(jm, a, a, w),
         f"{r*c*3*4/1e6:.0f}MB_traffic"),
        ("divergence_pallas_interp_4x4k",
         _time(lambda x, y: kd.sqdiff_rowsum(x, y, interpret=True),
               a[:4, :4096], b[:4, :4096], iters=3), "interpret_mode"),
        ("masked_acc_pallas_interp_4x4k",
         _time(lambda x, y, z: ka.masked_accumulate(x, y, z, interpret=True),
               a[:4, :4096], a[:4, :4096], w[:4], iters=3), "interpret_mode"),
    ]
    up = _uplink_ab()
    rows += [
        (f"uplink_fused_{up['shape']}", up["uplink_fused_us"],
         f"{up['uplink_packed_bytes']/1e6:.0f}MB_wire"),
        (f"uplink_unfused_{up['shape']}", up["uplink_unfused_us"],
         f"{up['uplink_fp32_bytes']/1e6:.0f}MB_fp32"),
    ]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", file=out)
    print(f"# uplink fusion speedup: {up['uplink_fused_speedup']:.2f}x, "
          f"wire bytes {up['uplink_packed_bytes']/1e6:.0f}MB vs fp32 "
          f"{up['uplink_fp32_bytes']/1e6:.0f}MB", file=out)
    return {"rows": rows, **up}


if __name__ == "__main__":
    run()
