"""§Roofline table: aggregate the dry-run JSON artifacts into the
per-(arch × shape × mesh) three-term table.

CSV: arch,shape,mesh,t_compute_ms,t_memory_ms,t_collective_ms,dominant,
     useful_ratio,model_gflops,coll_allreduce_gb,coll_allgather_gb
"""
from __future__ import annotations

import glob
import json
import os
import sys


def run(artifact_dir: str = "experiments/dryrun", out=sys.stdout):
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    if not rows:
        print("# no dry-run artifacts found in", artifact_dir, file=out)
        return []
    print("arch,shape,mesh,t_compute_ms,t_memory_ms,t_collective_ms,"
          "dominant,useful_ratio,model_gflops,coll_ar_gb,coll_ag_gb",
          file=out)
    for r in rows:
        coll = r.get("collective_by_type", {})
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['t_compute_s']*1e3:.2f},{r['t_memory_s']*1e3:.2f},"
              f"{r['t_collective_s']*1e3:.2f},{r['dominant']},"
              f"{r['useful_flops_ratio']:.4f},"
              f"{r['model_flops']/1e9:.1f},"
              f"{coll.get('all-reduce', 0)/1e9:.3f},"
              f"{coll.get('all-gather', 0)/1e9:.3f}", file=out)
    return rows


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
