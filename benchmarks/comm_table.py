"""Communication-overhead table for the paper's exact §III-A configuration
(VGG-9, K=20, n=4, T=1000): per-round and total uplink per algorithm.

This is the paper's 80 %-reduction headline, computed from the real VGG-9
parameter layout (not an approximation): CSV

    algo,uplink_per_round_mb,total_uplink_gb_T1000,savings_vs_fedavg
"""
from __future__ import annotations

import sys

import jax

import jax.numpy as jnp

from repro.core import UnitMap, round_comm, selection as sel
from repro.core.fedadp import comm_bytes as fedadp_bytes
from repro.core.wire import UNIT_HEADER_BYTES
from repro.federated.strategies.fedlama import expected_round_bytes
from repro.models import cnn


def run(out=sys.stdout, rounds: int = 1000):
    cfg = cnn.VGGConfig()
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)
    umap = UnitMap.build(params)
    k, n = 20, 4
    key = jax.random.PRNGKey(0)

    rows = []
    masks = {
        "fedldf": sel.topn_divergence(
            jax.random.uniform(key, (k, umap.num_units)), n),
        "fedavg": sel.full_participation(k, umap.num_units),
        "random": sel.random_per_layer(key, k, umap.num_units, n),
        "hdfl": sel.client_dropout(key, k, umap.num_units, n),
    }
    fedavg_up = None
    print("algo,uplink_per_round_mb,total_uplink_gb_T1000,savings_vs_fedavg",
          file=out)
    for algo, mask in masks.items():
        stats = round_comm(mask, umap,
                           divergence_feedback=(algo == "fedldf"))
        up = float(stats["uplink_total"])
        if algo == "fedavg":
            fedavg_up = up
        rows.append((algo, up))
    # FedADP at keep=0.2 (paper's equal-comm setting)
    rows.append(("fedadp", fedadp_bytes(params, k, 0.2)))
    # FedLAMA at the same equal-comm pinning (τ' = K/n = 5): steady-state
    # per-round bytes depend on the run's discrepancy trace, so the table
    # carries the model's bracket — 'hi' = every unit on the base interval
    # τ', 'lo' = every unit demoted to λτ' (λ=2).
    lama = expected_round_bytes(umap, k, tau=k // n, lam=2)
    rows.append(("fedlama_hi", lama["hi"]))
    rows.append(("fedlama_lo", lama["lo"]))
    # FedLDF + packed int8 wire format: same top-n mask, priced at the
    # PackedPayload rate — ceil(params·8/8) level bytes + the per-unit
    # scale/width header instead of fp32 unit sizes
    p = jnp.asarray(umap.unit_params, jnp.float32)
    packed8 = jnp.ceil(p * 8 / 8.0) + UNIT_HEADER_BYTES
    stats = round_comm(masks["fedldf"], umap, divergence_feedback=True,
                       unit_bytes_override=packed8)
    rows.append(("fedldf_q8_packed", float(stats["uplink_total"])))
    # ...and at the auto-allocation budget (4-bit average waterfill)
    packed_auto = jnp.ceil(p * 4 / 8.0) + UNIT_HEADER_BYTES
    stats = round_comm(masks["fedldf"], umap, divergence_feedback=True,
                       unit_bytes_override=packed_auto)
    rows.append(("fedldf_qauto4_packed", float(stats["uplink_total"])))

    # ---- adapter-only uplink (trainable-partition workload) ----
    # Savings here are measured against the *transformer's own* full-model
    # FedAvg upload (fedavg_lora_full), not the VGG-9 baseline above —
    # different model, separate reference.
    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.models.lora import inject_lora, lora_partition

    lcfg = get_config("qwen3-1.7b").reduced()
    lparams = inject_lora(jax.random.PRNGKey(1),
                          tfm.init_params(jax.random.PRNGKey(0), lcfg),
                          rank=4)
    trainable, _ = lora_partition(lparams).split(lparams)
    lumap = UnitMap.build(trainable)
    full_up = float(k * sum(l.size * l.dtype.itemsize
                            for l in jax.tree.leaves(lparams)))
    ln = max(1, round(lumap.num_units * n / 20))  # paper's n/K ratio
    lmask = sel.topn_divergence(
        jax.random.uniform(key, (k, lumap.num_units)), ln)
    stats = round_comm(lmask, lumap, divergence_feedback=True)
    lora_rows = [("fedavg_lora_full", full_up),
                 ("fedldf_lora", float(stats["uplink_total"]))]
    lp = jnp.asarray(lumap.unit_params, jnp.float32)
    stats = round_comm(lmask, lumap, divergence_feedback=True,
                       unit_bytes_override=jnp.ceil(lp * 8 / 8.0)
                       + UNIT_HEADER_BYTES)
    lora_rows.append(("fedldf_lora_q8_packed", float(stats["uplink_total"])))

    for algo, up in rows:
        sav = 1 - up / fedavg_up
        print(f"{algo},{up/1e6:.2f},{up*rounds/1e9:.2f},{sav:.4f}", file=out)
    for algo, up in lora_rows:
        sav = 1 - up / full_up
        print(f"{algo},{up/1e6:.2f},{up*rounds/1e9:.2f},{sav:.4f}", file=out)
    return dict(rows + lora_rows)


if __name__ == "__main__":
    run()
