"""Two-process ``jax.distributed`` smoke test (CPU, fail-soft).

    PYTHONPATH=src python -m benchmarks.dist_smoke [--processes 2]
        [--devices-per-process 2] [--timeout 180]

Validates the multi-host seam of the FL round engine end to end with real
OS processes on one machine: the parent picks a free coordinator port and
spawns N children; every child

1. calls :func:`repro.launch.mesh.init_distributed` (the idempotent
   ``jax.distributed.initialize`` wrapper),
2. builds the global mesh with ``make_client_mesh(processes=N)`` — the
   ``jax.make_mesh`` path over the GLOBAL device list, where each host's
   local devices sit contiguous on the 'clients' axis,
3. runs a tiny ``shard_map`` psum over the 'clients' axis and checks the
   result equals the global device count on every process.

**Fail-soft**: cross-process CPU collectives depend on the jax build
(some jaxlib wheels report "Multiprocess computations aren't implemented
on the CPU backend"). When distributed init never completes, children
hang, or the backend declares collectives unimplemented, the parent
prints ``SKIP`` and exits 0 — CI runs this as a canary (ci.yml
``dist-smoke``, ``continue-on-error``), not a gate. A wrong *result* (or
any other child error) after a successful distributed init does fail
(exit 1): that is the seam actually broken, not an unsupported
environment.
"""
from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys

_SKIP_EXIT = 42      # child → parent: environment can't run this, not a bug


def _child(coordinator: str, processes: int, pid: int) -> int:
    from repro.launch.mesh import (CLIENT_AXIS, init_distributed,
                                   make_client_mesh, shard_map_norep)
    info = init_distributed(coordinator_address=coordinator,
                            num_processes=processes, process_id=pid)
    print(f"[child {pid}] init ok: {info}", flush=True)
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    mesh = make_client_mesh(processes=processes)
    d = int(mesh.shape[CLIENT_AXIS])
    try:
        total = shard_map_norep(
            lambda x: jax.lax.psum(x, CLIENT_AXIS), mesh,
            in_specs=P(CLIENT_AXIS), out_specs=P())(jnp.ones((d,)))
        got = float(jax.device_get(total))
    except Exception as e:                          # noqa: BLE001
        # e.g. "Multiprocess computations aren't implemented on the CPU
        # backend" (jaxlib builds without CPU cross-process collectives):
        # environment, not the engine — signal SKIP to the parent
        if "implement" in str(e).lower():
            print(f"[child {pid}] SKIP: {e}", flush=True)
            return _SKIP_EXIT
        raise
    assert got == d, f"psum over {CLIENT_AXIS} gave {got}, want {d}"
    print(f"[child {pid}] psum over {d} global devices across "
          f"{info['process_count']} processes: OK", flush=True)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--devices-per-process", type=int, default=2)
    ap.add_argument("--timeout", type=float, default=180.0)
    ap.add_argument("--child", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--coordinator", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.child is not None:
        return _child(args.coordinator, args.processes, args.child)

    with socket.socket() as s:        # free port on loopback
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    coordinator = f"127.0.0.1:{port}"

    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "--xla_force_host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        flags + ["--xla_force_host_platform_device_count="
                 f"{args.devices_per_process}"]).strip()
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")

    procs = [subprocess.Popen(
        [sys.executable, "-m", "benchmarks.dist_smoke",
         "--processes", str(args.processes), "--child", str(i),
         "--coordinator", coordinator],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        for i in range(args.processes)]
    outs, codes = [], []
    for p in procs:
        try:
            out, _ = p.communicate(timeout=args.timeout)
        except subprocess.TimeoutExpired:
            p.kill()
            out, _ = p.communicate()
            out += "\n[parent] child timed out"
        outs.append(out)
        codes.append(p.returncode)

    if all(c == 0 for c in codes):
        for out in outs:
            print(out, end="")
        print(f"dist_smoke: OK ({args.processes} processes x "
              f"{args.devices_per_process} devices)")
        return 0
    # children distinguish environment limits (init never completed, or
    # collectives unimplemented → _SKIP_EXIT) from real engine failures
    if all(c == 0 or c == _SKIP_EXIT for c in codes) or \
            not all("init ok" in out for out in outs):
        print("dist_smoke: SKIP — jax.distributed unusable in this "
              f"environment (child exits {codes}); first child output:")
        print(outs[0], end="")
        return 0
    for out in outs:
        print(out, end="")
    print("dist_smoke: FAILED after successful distributed init")
    return 1


if __name__ == "__main__":
    sys.exit(main())
