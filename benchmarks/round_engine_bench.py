"""Round-engine benchmark: host-loop driver vs device-resident scan engine.

    PYTHONPATH=src python -m benchmarks.round_engine_bench [--mode floor|vgg]
        [--rounds N] [--reps R] [--skip-equivalence]

Measures rounds/sec of the two multi-round drivers on the paper's
VGG-9/CIFAR-10 protocol (N=50 clients, K=20 participants/round, FedLDF
top-n=4, B=32 per client):

- ``host``  — :func:`repro.federated.run_training` with the seed's host
  sampler: numpy client sampling, numpy per-client batch gathering,
  host→device batch upload, and per-round metric pulls.
- ``scan``  — :func:`repro.federated.run_training_scan`: the whole schedule
  in one jitted ``lax.scan``; sampling/gathering/aggregation/accounting all
  device-resident, zero per-round host work.

Two workloads:

- ``floor`` (default): a near-zero-FLOP probe model (per-image channel
  means → linear head) over CIFAR-10-shaped federated shards. Local
  training math is negligible, so rounds/sec measures the *round-loop
  machinery* itself — exactly what the engine rebuilds. This is the regime
  of the ISSUE motivation: on accelerator-backed hosts every host↔device
  crossing is orders of magnitude more expensive than here (shared-memory
  CPU "device"), so the measured speedup is a *lower bound* on the
  accelerator-side win.
- ``vgg``: reduced VGG-9 end-to-end. On CPU the conv forward/backward
  dominates wall-clock identically in both drivers, so this shows the
  compute-bound limit (speedup → 1).

Also verifies the engine against the reference oracle: with the shared JAX
key schedule (``run_training(sampler="jax")``), host-driven and scanned
training must produce the same final parameters to fp32 tolerance
(fedldf + fedavg, vmap and scan client modes).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.data import (ClientShards, FederatedData, iid_partition,
                        make_image_dataset)
from repro.federated import (FLConfig, TelemetryConfig, run_training,
                             run_training_scan)
from repro.models import cnn

# paper §III-A protocol scale. The floor workload uses a small local batch
# (B=8) so the round loop — not batch-gather memory bandwidth, which is
# identical host work either way — dominates; vgg keeps the paper's B=32.
N_CLIENTS, K, TOP_N = 50, 20, 4
BATCH_BY_MODE = {"floor": 8, "vgg": 32}
EQUIV_TOL = 2e-5   # host-vs-scan fp32 agreement threshold (single source)


def _head_params(key):
    return {"head": {"w": jax.random.normal(key, (3, 10)) * 0.01,
                     "b": jnp.zeros((10,))}}


def _head_loss(params, batch):
    """Near-zero-FLOP probe: per-image channel means -> linear head.

    Keeps the full batch gather live (reads every pixel once) while making
    local-training FLOPs negligible, so the measurement isolates the round
    loop rather than conv throughput.
    """
    feat = batch["images"].mean(axis=(1, 2))                 # (B, C)
    logits = feat @ params["head"]["w"] + params["head"]["b"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)
    return nll.mean()


def _make_task(mode: str, num_train: int, seed: int = 0):
    train, _ = make_image_dataset(num_train=num_train, num_test=16, seed=1)
    parts = iid_partition(train.ys, N_CLIENTS, seed=seed)
    data = FederatedData(train.xs, train.ys, parts)
    if mode == "floor":
        params = _head_params(jax.random.PRNGKey(seed))
        loss = _head_loss
    else:
        cfg = cnn.VGGConfig().reduced()
        params = cnn.init_params(jax.random.PRNGKey(seed), cfg)

        def loss(p, b, cfg=cfg):
            return cnn.classify_loss(p, cfg, b)

    flcfg = FLConfig(algo="fedldf", num_clients=N_CLIENTS,
                     clients_per_round=K, top_n=TOP_N, mode="vmap",
                     batch_per_client=BATCH_BY_MODE[mode])
    return params, loss, data, flcfg


def _best_rate(fn, rounds: int, reps: int) -> float:
    """Best-of-reps rounds/sec (first call outside timing warms the jit
    caches, so compilation never pollutes the measurement)."""
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return rounds / best


def run(mode: str = "floor", rounds: int = 300, reps: int = 5,
        num_train: int = 5000, out=sys.stdout,
        ledger_path: str | None = None) -> dict:
    params, loss, data, flcfg = _make_task(mode, num_train)
    rounds = max(1, rounds)
    if mode == "vgg":
        rounds = min(rounds, 10)   # conv-bound: keep wall time sane on CPU

    # upload the dataset once — per-round gathering is what's under test,
    # not the one-time host→device conversion
    shards = ClientShards.from_federated(data)
    host_rate = _best_rate(
        lambda: run_training(params, loss, data, flcfg, rounds=rounds,
                             seed=0, sampler="host"), rounds, reps)
    scan_rate = _best_rate(
        lambda: run_training_scan(params, loss, shards, flcfg,
                                  rounds=rounds, seed=0), rounds, reps)
    # the stateful-strategy rate: fedlama threads cross-round interval
    # state through the scan carry, so scan_rate vs fedlama_rate bounds the
    # state-seam overhead per round (trendline.py gates it per-PR)
    lama_cfg = FLConfig(algo="fedlama", num_clients=N_CLIENTS,
                        clients_per_round=K, top_n=TOP_N, mode="vmap",
                        batch_per_client=BATCH_BY_MODE[mode])
    fedlama_rate = _best_rate(
        lambda: run_training_scan(params, loss, shards, lama_cfg,
                                  rounds=rounds, seed=0), rounds, reps)
    # telemetry overhead: the SAME scan workload with full in-jit taps
    # (per-layer divergence/selection vectors + full (K, U) masks) AND the
    # JSONL round ledger enabled, so the measured rate pays both the
    # widened stacked outputs and the host-side serialisation. The
    # append-mode ledger is truncated before every timed rep so the kept
    # artifact (``ledger_path``; CI uploads it next to BENCH_ci.json)
    # holds exactly one run.
    if ledger_path is None:
        ledger_path = os.path.join(
            tempfile.mkdtemp(prefix="round_engine_bench_"),
            "TELEMETRY.jsonl")
    tele_cfg = dataclasses.replace(
        flcfg, telemetry=TelemetryConfig(ledger_path=ledger_path,
                                         run_id=f"{mode}-scan-telemetry"))

    def _telemetry_run():
        open(ledger_path, "w").close()
        run_training_scan(params, loss, shards, tele_cfg, rounds=rounds,
                          seed=0)

    telemetry_rate = _best_rate(_telemetry_run, rounds, reps)
    telemetry_ratio = telemetry_rate / scan_rate
    speedup = scan_rate / host_rate
    print(f"workload={mode} N={N_CLIENTS} K={K} n={TOP_N} "
          f"B={BATCH_BY_MODE[mode]} rounds={rounds}", file=out)
    print(f"host loop   : {host_rate:8.1f} rounds/s "
          f"({1e3/host_rate:6.2f} ms/round)", file=out)
    print(f"scan engine : {scan_rate:8.1f} rounds/s "
          f"({1e3/scan_rate:6.2f} ms/round)", file=out)
    print(f"fedlama     : {fedlama_rate:8.1f} rounds/s "
          f"({1e3/fedlama_rate:6.2f} ms/round; scan engine + cross-round "
          f"state carry)", file=out)
    print(f"telemetry   : {telemetry_rate:8.1f} rounds/s "
          f"({1e3/telemetry_rate:6.2f} ms/round; full taps + JSONL "
          f"ledger = {telemetry_ratio:.2f}x of plain scan)", file=out)
    print(f"speedup     : {speedup:.2f}x  (shared-memory CPU; every "
          f"host<->device crossing the engine removes is far costlier on "
          f"accelerator hosts)", file=out)
    return {"mode": mode, "host_rate": host_rate, "scan_rate": scan_rate,
            "fedlama_rate": fedlama_rate,
            "telemetry_rate": telemetry_rate,
            "telemetry_ratio": telemetry_ratio,
            "telemetry_ledger": ledger_path, "speedup": speedup}


def equivalence_check(rounds: int = 4, out=sys.stdout) -> float:
    """Host driver (JAX sampler) vs scan engine: same seed, same params."""
    cfg = cnn.VGGConfig().reduced()
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)

    def loss(p, b):
        return cnn.classify_loss(p, cfg, b)

    train, _ = make_image_dataset(num_train=400, num_test=16, seed=1)
    parts = iid_partition(train.ys, 8, seed=0)
    data = FederatedData(train.xs, train.ys, parts)
    shards = ClientShards.from_federated(data)
    worst = 0.0
    for algo in ("fedldf", "fedavg"):
        fl = FLConfig(algo=algo, num_clients=8, clients_per_round=4,
                      top_n=2, mode="vmap", batch_per_client=8)
        ph, _ = run_training(params, loss, shards, fl, rounds=rounds,
                             seed=0, sampler="jax")
        ps, _ = run_training_scan(params, loss, shards, fl, rounds=rounds,
                                  seed=0)
        diff = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(ph), jax.tree.leaves(ps)))
        worst = max(worst, diff)
        status = "OK" if diff < EQUIV_TOL else "FAIL"
        print(f"equivalence {algo:7s}: max|host-scan| = {diff:.2e}  "
              f"[{status}]", file=out)
    return worst


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("floor", "vgg"), default="floor")
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--num-train", type=int, default=5000)
    ap.add_argument("--skip-equivalence", action="store_true")
    ap.add_argument("--telemetry-ledger", default=None,
                    help="keep the telemetry run's JSONL ledger at this "
                         "path (default: a temp file)")
    args = ap.parse_args(argv)
    run(mode=args.mode, rounds=args.rounds, reps=args.reps,
        num_train=args.num_train, ledger_path=args.telemetry_ledger)
    if not args.skip_equivalence:
        worst = equivalence_check()
        if worst >= EQUIV_TOL:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
