"""Benchmark harness — one section per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--fl-rounds N] [--skip-fl]
        [--ci] [--json PATH]

Sections:
  [kernel]    FedLDF hot-spot op microbenches (name,us_per_call,derived)
  [comm]      paper §III 80 %-reduction table (VGG-9, K=20, n=4)
  [bound]     Theorem 1 gap-bound verification
  [engine]    host-loop driver vs device-resident scan engine (rounds/sec
              + host-vs-scan fp32 equivalence; round_engine_bench.py)
  [shard]     client-axis sharding over a forced-8-device CPU mesh
              (rounds/sec vs mesh size; shard_engine_bench.py)
  [fig3/4]    test-error-vs-communication curves, IID + Dirichlet(α=1)
  [roofline]  dry-run roofline table (if experiments/dryrun exists)

``--ci`` shrinks every section to smoke shapes (tiny round counts, one rep)
so the whole harness fits in a CI job; ``--json`` dumps the per-section
results (the BENCH_ci.json artifact CI uploads on every push, so the repo's
perf trajectory is recorded rather than anecdotal).
"""
from __future__ import annotations

import argparse
import json
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fl-rounds", type=int, default=30)
    ap.add_argument("--skip-fl", action="store_true")
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--ci", action="store_true",
                    help="smoke shapes: tiny rounds/reps, skip fig3/4 sweep")
    ap.add_argument("--json", default=None,
                    help="write per-section results as JSON")
    ap.add_argument("--telemetry-ledger", default=None,
                    help="keep the [engine] telemetry run's JSONL ledger "
                         "at this path (CI uploads it next to "
                         "BENCH_ci.json; default: a temp file)")
    args = ap.parse_args(argv)

    results: dict = {"ci": args.ci}
    t0 = time.time()
    print("# === [kernel] hot-spot microbenchmarks ===")
    from benchmarks import kernel_bench
    results["kernel"] = kernel_bench.run()

    print("# === [comm] paper comm-overhead table (VGG-9, K=20, n=4) ===")
    from benchmarks import comm_table
    results["comm"] = comm_table.run()

    print("# === [bound] Theorem 1 verification ===")
    from benchmarks import bound
    results["bound"] = bound.run()

    if not args.skip_fl:
        print("# === [engine] host loop vs device-resident scan engine ===")
        from benchmarks import round_engine_bench
        results["engine"] = round_engine_bench.run(
            rounds=20 if args.ci else 150, reps=1 if args.ci else 3,
            ledger_path=args.telemetry_ledger)
        if round_engine_bench.equivalence_check() >= \
                round_engine_bench.EQUIV_TOL:
            raise SystemExit("[engine] host-vs-scan equivalence FAILED")

        print("# === [shard] client-axis sharding vs mesh size ===")
        from benchmarks import shard_engine_bench
        # keep the client-heavy shape even in CI (smaller N is overhead-
        # bound and the speedup number stops meaning anything); trim
        # rounds/reps instead
        # the population row (N=1e6 synthetic, K=4096) rides this section;
        # CI shrinks N/K so the smoke job stays minutes, not tens of them
        results["shard"] = shard_engine_bench.run(
            rounds=10 if args.ci else 30, reps=1 if args.ci else 5,
            pop_clients=100_000 if args.ci else 1_000_000,
            pop_cohort=512 if args.ci else 4096,
            pop_rounds=2 if args.ci else 3)
        if not results["shard"].get("equiv_ok"):
            raise SystemExit("[shard] sharded-vs-unsharded equivalence "
                             "FAILED")

        if not args.ci:
            print("# === [fig3/fig4] error vs communication ===")
            from benchmarks import fl_comparison
            res = fl_comparison.run(paper_scale=args.paper_scale,
                                    rounds=args.fl_rounds)
            fl_comparison.summarize(res)

            print("# === [n-sweep] Theorem-1 n/K trade-off ablation ===")
            from benchmarks import n_sweep
            n_sweep.run(rounds=max(20, args.fl_rounds // 2))

    print("# === [roofline] dry-run table ===")
    from benchmarks import roofline_table
    roofline_table.run()

    results["wall_time_s"] = time.time() - t0
    print(f"# total benchmark wall time: {results['wall_time_s']:.1f}s")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, default=float)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
