"""Benchmark harness — one section per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--fl-rounds N] [--skip-fl]

Sections:
  [kernel]    FedLDF hot-spot op microbenches (name,us_per_call,derived)
  [comm]      paper §III 80 %-reduction table (VGG-9, K=20, n=4)
  [bound]     Theorem 1 gap-bound verification
  [engine]    host-loop driver vs device-resident scan engine (rounds/sec
              + host-vs-scan fp32 equivalence; round_engine_bench.py)
  [fig3/4]    test-error-vs-communication curves, IID + Dirichlet(α=1)
  [roofline]  dry-run roofline table (if experiments/dryrun exists)
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fl-rounds", type=int, default=30)
    ap.add_argument("--skip-fl", action="store_true")
    ap.add_argument("--paper-scale", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.time()
    print("# === [kernel] hot-spot microbenchmarks ===")
    from benchmarks import kernel_bench
    kernel_bench.run()

    print("# === [comm] paper comm-overhead table (VGG-9, K=20, n=4) ===")
    from benchmarks import comm_table
    comm_table.run()

    print("# === [bound] Theorem 1 verification ===")
    from benchmarks import bound
    bound.run()

    if not args.skip_fl:
        print("# === [engine] host loop vs device-resident scan engine ===")
        from benchmarks import round_engine_bench
        round_engine_bench.run(rounds=150, reps=3)
        if round_engine_bench.equivalence_check() >= \
                round_engine_bench.EQUIV_TOL:
            raise SystemExit("[engine] host-vs-scan equivalence FAILED")

        print("# === [fig3/fig4] error vs communication ===")
        from benchmarks import fl_comparison
        res = fl_comparison.run(paper_scale=args.paper_scale,
                                rounds=args.fl_rounds)
        fl_comparison.summarize(res)

        print("# === [n-sweep] Theorem-1 n/K trade-off ablation ===")
        from benchmarks import n_sweep
        n_sweep.run(rounds=max(20, args.fl_rounds // 2))

    print("# === [roofline] dry-run table ===")
    from benchmarks import roofline_table
    roofline_table.run()

    print(f"# total benchmark wall time: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
