"""Registry-wide smoke builds: every configs/ entry must (a) be reachable
through the registry and (b) produce a working reduced-dims forward —
including with LoRA adapters injected (forward-exact at init)."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ARCHS, get_config, vgg9
from repro.models import cnn
from repro.models import transformer as tfm
from repro.models.lora import inject_lora, lora_partition

CONFIG_DIR = (pathlib.Path(__file__).resolve().parents[1]
              / "src" / "repro" / "configs")


def test_every_config_module_is_registered():
    """No orphan configs/*.py: each module is reachable via ARCHS ∪ vgg9."""
    modules = {p.stem for p in CONFIG_DIR.glob("*.py")} - {"__init__"}
    registered = set(ARCHS.values()) | {"vgg9_cifar10"}
    assert modules == registered


def _smoke_batch(cfg, batch=1, seq=8):
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                              cfg.vocab_size)
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(
            jax.random.PRNGKey(2),
            (batch, 4, cfg.frontend_dim or cfg.d_model), jnp.float32)
    return toks, enc


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_forward_builds(arch_id):
    cfg = get_config(arch_id).reduced()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    toks, enc = _smoke_batch(cfg)
    logits, aux = tfm.forward(params, cfg, toks, enc_inputs=enc)
    assert logits.shape == (*toks.shape, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_lora_injection_is_forward_exact_at_init(arch_id):
    """b=0 init ⇒ adapted forward == base forward bit-for-bit, and the
    lora partition is non-empty for every family (ssm families adapt
    in_proj/out_proj)."""
    cfg = get_config(arch_id).reduced()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    adapted = inject_lora(jax.random.PRNGKey(1), params, rank=2)
    part = lora_partition(adapted)
    assert len(part.trainable_paths) > 0
    toks, enc = _smoke_batch(cfg)
    base, _ = tfm.forward(params, cfg, toks, enc_inputs=enc)
    lora, _ = tfm.forward(adapted, cfg, toks, enc_inputs=enc)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(lora))


def test_vgg9_reduced_forward_builds():
    cfg = vgg9().reduced()
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)
    images = jax.random.normal(jax.random.PRNGKey(1),
                               (2, cfg.image_size, cfg.image_size, 3))
    logits = cnn.forward(params, cfg, images)
    assert logits.shape == (2, cfg.num_classes)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
