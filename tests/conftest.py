"""Shared fixtures + deterministic device environment.

The JAX device count is frozen at first import, so it MUST be pinned before
any test module imports jax — otherwise the suite silently runs with
whatever device count the ambient environment happens to force, and
"passes locally, differs in CI" bugs appear. Policy:

- platform defaults to CPU (``JAX_PLATFORMS=cpu``) unless the caller set it;
- the forced host-device count defaults to 1 (the seed behaviour) and is
  raised explicitly via ``REPRO_TEST_DEVICES=8`` (what the CI sharded job
  sets) or by passing ``--xla_force_host_platform_device_count`` yourself;
- launch/dryrun.py still forces 512 devices in its own subprocess — that
  path overrides XLA_FLAGS itself and is unaffected.

Sharding tests (tests/test_shard_engine.py) skip cleanly when fewer devices
are visible than a case needs, so the default single-device run stays green.
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    _n = os.environ.get("REPRO_TEST_DEVICES", "1")
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n}").strip()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
