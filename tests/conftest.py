"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches run on
the single real CPU device; only launch/dryrun.py (own process) forces 512."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
