"""Client-axis sharding: sharded-vs-single-device trajectory equivalence.

Runs the federated round engine with the stacked client axis sharded over a
'clients' mesh of 1/2/4 devices and checks the trajectory (params, losses,
comm totals) against the unsharded ``mesh=None`` reference on a fixed seed.

Needs forced host devices: run with ``REPRO_TEST_DEVICES=8`` (see
tests/conftest.py) or ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
— multi-device cases skip cleanly on a plain single-device run. Tolerance
is fp32-tight, not bit-exact: the sharded aggregation pre-reduces each
device's clients before the cross-device psum, which changes the fp32
summation order (documented in core/aggregation.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.round_engine_bench import EQUIV_TOL
from repro.data import FederatedData, iid_partition, make_image_dataset
from repro.federated import FLConfig, run_training, run_training_scan
from repro.launch.mesh import make_client_mesh

N_CLIENTS, K = 8, 4
ATOL = EQUIV_TOL   # single source: host-vs-scan and sharded-vs-unsharded
                   # agreement share one fp32 threshold

needs_devices = [
    pytest.param(d, marks=pytest.mark.skipif(
        len(jax.devices()) < d,
        reason=f"needs {d} devices; set REPRO_TEST_DEVICES=8 (or XLA_FLAGS="
               "--xla_force_host_platform_device_count=8)"))
    for d in (1, 2, 4)
]


def _mlp_params(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    return {
        "l1": {"w": jax.random.normal(ks[0], (3072, 16)) * 0.02,
               "b": jnp.zeros((16,))},
        "head": {"w": jax.random.normal(ks[1], (16, 10)) * 0.1,
                 "b": jnp.zeros((10,))},
    }


def _loss(params, batch):
    x = batch["images"].reshape(batch["images"].shape[0], -1)
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    logits = h @ params["head"]["w"] + params["head"]["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, batch["labels"][:, None],
                                axis=-1).mean()


@pytest.fixture(scope="module")
def task():
    train, _ = make_image_dataset(num_train=320, num_test=16, seed=1)
    parts = iid_partition(train.ys, N_CLIENTS, seed=0)
    data = FederatedData(train.xs, train.ys, parts)
    return _mlp_params(), data


def _assert_trees_close(a, b, atol=ATOL):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol)


def _cfg(mesh, algo="fedldf", **kw):
    return FLConfig(algo=algo, num_clients=N_CLIENTS, clients_per_round=K,
                    top_n=2, mode="vmap", batch_per_client=8, mesh=mesh,
                    **kw)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("algo", ["fedldf", "fedavg", "fedlp", "fedadp",
                                  "fedlama"])
@pytest.mark.parametrize("mesh_size", needs_devices)
def test_sharded_engine_matches_unsharded(task, algo, mesh_size):
    """Fixed seed ⇒ same trajectory across mesh sizes 1/2/4 and mesh=None,
    for the paper algorithm (divergence all-gather + top-n), FedAvg,
    FedLP (replicated Bernoulli selection + additive keep-mask comm),
    FedADP (per-leaf masked psum halves — the capability flipped by the
    state-seam PR), and FedLAMA (replicated cross-round interval state
    threaded through the shard_map carry)."""
    params, data = task
    p0, l0 = run_training_scan(params, _loss, data, _cfg(None, algo),
                               rounds=4, seed=3)
    p1, l1 = run_training_scan(params, _loss, data,
                               _cfg(make_client_mesh(mesh_size), algo),
                               rounds=4, seed=3)
    _assert_trees_close(p0, p1)
    np.testing.assert_allclose(l0.losses, l1.losses, atol=ATOL)
    assert l0.meter.uplink_bytes == pytest.approx(l1.meter.uplink_bytes)
    assert l0.meter.downlink_bytes == pytest.approx(l1.meter.downlink_bytes)
    assert l1.meter.rounds == 4


@pytest.mark.parametrize("mesh_size", needs_devices)
def test_sharded_host_driver_matches_engine(task, mesh_size):
    """The host-loop driver under a mesh agrees with the scanned engine
    under the same mesh (shared key schedule)."""
    params, data = task
    mesh = make_client_mesh(mesh_size)
    ph, lh = run_training(params, _loss, data, _cfg(mesh), rounds=3, seed=0,
                          sampler="jax")
    ps, ls = run_training_scan(params, _loss, data, _cfg(mesh), rounds=3,
                               seed=0)
    _assert_trees_close(ph, ps)
    assert lh.meter.uplink_bytes == pytest.approx(ls.meter.uplink_bytes)


@pytest.mark.parametrize("mesh_size", needs_devices)
def test_residual_store_under_sharding(task, mesh_size):
    """Error-feedback residuals: per-client rows gathered/scattered through
    the sharded round must reproduce the unsharded EF trajectory — and EF
    must still have its cross-round effect (the PR-1 regression) when the
    rows live sharded across devices."""
    params, data = task

    def efcfg(mesh, ef):
        return _cfg(mesh, quantize_bits=4, error_feedback=ef)

    mesh = make_client_mesh(mesh_size)
    p0, _ = run_training_scan(params, _loss, data, efcfg(None, True),
                              rounds=3, seed=0)
    p1, _ = run_training_scan(params, _loss, data, efcfg(mesh, True),
                              rounds=3, seed=0)
    _assert_trees_close(p0, p1)
    # EF-on vs EF-off must diverge after round 1 under sharding too
    p_off, _ = run_training_scan(params, _loss, data, efcfg(mesh, False),
                                 rounds=3, seed=0)
    diff = max(float(jnp.abs(a - b).max()) for a, b in
               zip(jax.tree.leaves(p1), jax.tree.leaves(p_off)))
    assert diff > 1e-6, "error feedback lost its effect under sharding"


@pytest.mark.parametrize("mesh_size", needs_devices)
def test_quantized_upload_no_ef_under_sharding(task, mesh_size):
    """Quantized uploads without error feedback (residuals=None inside the
    shard_map body) also match the unsharded path."""
    params, data = task
    p0, l0 = run_training_scan(params, _loss, data,
                               _cfg(None, quantize_bits=4), rounds=2, seed=0)
    p1, l1 = run_training_scan(params, _loss, data,
                               _cfg(make_client_mesh(mesh_size),
                                    quantize_bits=4), rounds=2, seed=0)
    _assert_trees_close(p0, p1)
    assert l0.meter.uplink_bytes == pytest.approx(l1.meter.uplink_bytes)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("mesh_size", needs_devices)
def test_round_comm_axis_name_matches_global(mesh_size):
    """Sharded comm accounting: psum'ing local selection rows inside
    shard_map must reproduce the global round_comm totals exactly (byte
    counts are integer-valued floats — no tolerance needed)."""
    from functools import partial

    from jax.sharding import PartitionSpec as P

    from repro.core.comm import round_comm
    from repro.core.units import UnitMap
    from repro.launch.mesh import shard_map_norep

    params = _mlp_params()
    umap = UnitMap.build(params)
    k = 4
    selection = jnp.asarray(
        np.random.default_rng(0).integers(0, 2, (k, umap.num_units)),
        jnp.float32)
    want = round_comm(selection, umap)
    mesh = make_client_mesh(mesh_size)
    got = shard_map_norep(
        partial(round_comm, umap=umap, axis_name="clients"), mesh,
        in_specs=P("clients"), out_specs=P())(selection)
    for key in want:
        assert float(want[key]) == pytest.approx(float(got[key])), key


@pytest.mark.parametrize("mesh_size", needs_devices)
def test_aggregate_stacked_axis_name_matches_global(mesh_size):
    """The standalone sharded entry point — aggregate_stacked(...,
    axis_name='clients') on local rows inside shard_map — must reproduce
    the global unsharded aggregation, including zero-denominator fallback
    units (one column is forced dead)."""
    from jax.sharding import PartitionSpec as P

    from repro.core import aggregation as agg
    from repro.core.units import UnitMap
    from repro.launch.mesh import shard_map_norep

    params = _mlp_params()
    umap = UnitMap.build(params)
    k = 4
    rng = np.random.default_rng(1)
    stacked = jax.tree.map(
        lambda l: jnp.asarray(rng.normal(size=(k,) + l.shape), jnp.float32),
        params)
    selection = jnp.asarray(rng.integers(0, 2, (k, umap.num_units)),
                            jnp.float32).at[:, 0].set(0.0)   # dead unit
    sizes = jnp.asarray(rng.integers(1, 50, (k,)), jnp.float32)
    want = agg.aggregate_stacked(stacked, umap, selection, sizes,
                                 fallback=params)
    mesh = make_client_mesh(mesh_size)
    got = shard_map_norep(
        lambda st, sel, sz: agg.aggregate_stacked(
            st, umap, sel, sz, fallback=params, axis_name="clients"),
        mesh, in_specs=(P("clients"), P("clients"), P("clients")),
        out_specs=P())(stacked, selection, sizes)
    _assert_trees_close(want, got, atol=1e-6)


def test_mesh_config_validation():
    """FLConfig rejects meshes the sharded round can't honour."""
    if len(jax.devices()) >= 2:
        mesh = make_client_mesh(2)
        with pytest.raises(AssertionError):   # K=5 not divisible by 2
            FLConfig(num_clients=10, clients_per_round=5, top_n=2, mesh=mesh)
        with pytest.raises(AssertionError):   # scan mode can't shard clients
            FLConfig(num_clients=8, clients_per_round=4, top_n=2,
                     mode="scan", mesh=mesh)
    from repro.launch.mesh import client_mesh_size, make_host_mesh
    with pytest.raises(ValueError):           # no 'clients' axis
        client_mesh_size(make_host_mesh(1, 1))
    with pytest.raises(ValueError):           # more devices than exist
        make_client_mesh(len(jax.devices()) + 1)


def test_client_shards_place_preserves_gather(task):
    """Mesh placement (replication) must not change gathered batches."""
    from repro.data import ClientShards
    _, data = task
    shards = ClientShards.from_federated(data)
    placed = shards.place(make_client_mesh(len(jax.devices())))
    clients = jnp.array([1, 3, 5, 6])
    key = jax.random.PRNGKey(7)
    b0 = shards.gather(clients, 4, key)
    b1 = placed.gather(clients, 4, key)
    np.testing.assert_array_equal(np.asarray(b0["images"]),
                                  np.asarray(b1["images"]))
    np.testing.assert_array_equal(np.asarray(b0["labels"]),
                                  np.asarray(b1["labels"]))
