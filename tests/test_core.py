"""Core FedLDF: unit map, divergence (Eq. 3), selection (Eq. 4),
aggregation (Eq. 5/6), communication accounting, convergence bound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional [test] extra — deterministic fallbacks below
    HAVE_HYPOTHESIS = False

from repro.core import (BoundParams, UnitMap, aggregate_stacked,
                        asymptotic_gap, contraction_A, fedavg_stacked,
                        round_comm, selection as sel, streaming_add,
                        streaming_finalize, streaming_init, unit_weights)
from repro.core import convergence as conv


def _params(key=0):
    k = jax.random.PRNGKey(key)
    ks = jax.random.split(k, 4)
    return {
        "embed": {"w": jax.random.normal(ks[0], (32, 8))},
        "blocks": {"a": jax.random.normal(ks[1], (3, 8, 8)),
                   "b": jax.random.normal(ks[2], (3, 8))},
        "final": {"n": jax.random.normal(ks[3], (8,))},
    }


def _np_divergence(p, r, umap):
    out = np.zeros(umap.num_units)
    for key, (off, n) in umap.spans.items():
        for a, b in zip(jax.tree.leaves(p[key]), jax.tree.leaves(r[key])):
            a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
            if n > 1:
                out[off:off + n] += ((a - b) ** 2).reshape(n, -1).sum(1)
            else:
                out[off] += ((a - b) ** 2).sum()
    return np.sqrt(out)


# ----------------------------------------------------------------------
class TestUnitMap:
    def test_build(self):
        umap = UnitMap.build(_params())
        assert umap.names == ("blocks/0", "blocks/1", "blocks/2", "embed",
                              "final")
        assert umap.unit_bytes[0] == (8 * 8 + 8) * 4
        assert umap.unit_bytes[3] == 32 * 8 * 4
        assert umap.total_params == 3 * 72 + 256 + 8

    def test_divergence_matches_numpy(self):
        p, r = _params(0), _params(1)
        umap = UnitMap.build(p)
        np.testing.assert_allclose(umap.divergence(p, r),
                                   _np_divergence(p, r, umap), rtol=1e-5)

    def test_divergence_zero_for_identical(self):
        p = _params()
        umap = UnitMap.build(p)
        np.testing.assert_allclose(umap.divergence(p, p), 0.0, atol=1e-7)

    def test_scale_by_unit(self):
        p = _params()
        umap = UnitMap.build(p)
        scale = jnp.arange(umap.num_units, dtype=jnp.float32)
        out = umap.scale_by_unit(p, scale)
        np.testing.assert_allclose(out["blocks"]["a"][1],
                                   np.asarray(p["blocks"]["a"][1]) * 1.0)
        np.testing.assert_allclose(out["blocks"]["a"][2],
                                   np.asarray(p["blocks"]["a"][2]) * 2.0)
        np.testing.assert_allclose(out["embed"]["w"],
                                   np.asarray(p["embed"]["w"]) * 3.0)

    def test_jit_and_scan_safe(self):
        p, r = _params(0), _params(1)
        umap = UnitMap.build(p)
        d1 = jax.jit(umap.divergence)(p, r)
        np.testing.assert_allclose(d1, umap.divergence(p, r), rtol=1e-6)


# ----------------------------------------------------------------------
class TestSelection:
    def test_topn_exact(self):
        divs = jnp.array([[3.0, 0.0], [1.0, 2.0], [2.0, 1.0]])  # (K=3, U=2)
        s = sel.topn_divergence(divs, 2)
        np.testing.assert_array_equal(s, [[1, 0], [0, 1], [1, 1]])

    @staticmethod
    def _check_topn_properties(k, u, n, seed):
        n = min(n, k)
        divs = jax.random.uniform(jax.random.PRNGKey(seed), (k, u))
        s = np.asarray(sel.topn_divergence(divs, n))
        assert set(np.unique(s)) <= {0.0, 1.0}
        np.testing.assert_array_equal(s.sum(0), np.full(u, n))
        # selected divergences dominate unselected, per column
        for col in range(u):
            chosen = np.asarray(divs)[:, col][s[:, col] == 1]
            rest = np.asarray(divs)[:, col][s[:, col] == 0]
            if len(rest):
                assert chosen.min() >= rest.max() - 1e-6

    # deterministic fallback grid — covers the invariant without hypothesis
    @pytest.mark.parametrize("k,u,n,seed", [
        (2, 1, 1, 0), (3, 4, 2, 1), (12, 9, 12, 7), (5, 3, 5, 42),
        (7, 6, 3, 123), (9, 1, 4, 999983), (4, 2, 1, 31337),
    ])
    def test_topn_properties_cases(self, k, u, n, seed):
        self._check_topn_properties(k, u, n, seed)

    if HAVE_HYPOTHESIS:
        @settings(max_examples=30, deadline=None)
        @given(k=st.integers(2, 12), u=st.integers(1, 9),
               n=st.integers(1, 12), seed=st.integers(0, 10**6))
        def test_topn_properties(self, k, u, n, seed):
            self._check_topn_properties(k, u, n, seed)

    def test_random_per_layer_counts(self):
        s = np.asarray(sel.random_per_layer(jax.random.PRNGKey(0), 10, 7, 3))
        np.testing.assert_array_equal(s.sum(0), np.full(7, 3))

    def test_client_dropout_rows(self):
        s = np.asarray(sel.client_dropout(jax.random.PRNGKey(0), 10, 7, 4))
        # whole-row selection: every row all-ones or all-zeros
        assert set(s.sum(1)) <= {0.0, 7.0}
        assert s.sum() == 4 * 7

    def test_full(self):
        assert np.asarray(sel.full_participation(3, 2)).sum() == 6


# ----------------------------------------------------------------------
class TestAggregation:
    def _stacked(self, k=4):
        base = _params()
        return jax.tree.map(
            lambda l: jnp.stack([l * (i + 1.0) for i in range(k)]), base)

    def test_eq5_manual(self):
        """Eq. 5 against a hand-computed single-unit case."""
        g = _params()
        umap = UnitMap.build(g)
        sp = self._stacked(2)
        selection = jnp.zeros((2, umap.num_units)).at[0, 3].set(1.0) \
            .at[1, 3].set(1.0).at[0, 0].set(1.0).at[1, 4].set(1.0)
        sizes = jnp.array([1.0, 3.0])
        out = aggregate_stacked(sp, umap, selection, sizes, fallback=g)
        # unit 3 = embed: (1·1·θ + 3·2·θ)/(1+3)
        np.testing.assert_allclose(
            out["embed"]["w"],
            np.asarray(g["embed"]["w"]) * (1 * 1 + 3 * 2) / 4, rtol=1e-5)
        # unit 0 = blocks/0 only client 0: θ·1
        np.testing.assert_allclose(out["blocks"]["a"][0],
                                   np.asarray(g["blocks"]["a"][0]), rtol=1e-5)
        # blocks/1, blocks/2 unselected -> fallback to g
        np.testing.assert_allclose(out["blocks"]["a"][1],
                                   np.asarray(g["blocks"]["a"][1]), rtol=1e-5)
        # unit 4 = final only client 1 (×2)
        np.testing.assert_allclose(out["final"]["n"],
                                   np.asarray(g["final"]["n"]) * 2, rtol=1e-5)

    def test_full_selection_equals_fedavg(self):
        g = _params()
        umap = UnitMap.build(g)
        sp = self._stacked(3)
        sizes = jnp.array([2.0, 5.0, 3.0])
        s = sel.full_participation(3, umap.num_units)
        a = aggregate_stacked(sp, umap, s, sizes, fallback=g)
        b = fedavg_stacked(sp, sizes)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_allclose(x, y, rtol=1e-5)

    def test_streaming_equals_stacked(self):
        g = _params()
        umap = UnitMap.build(g)
        k = 4
        sp = self._stacked(k)
        sizes = jnp.array([1.0, 2.0, 3.0, 4.0])
        divs = jax.vmap(lambda p: umap.divergence(p, g))(sp)
        s = sel.topn_divergence(divs, 2)
        stacked = aggregate_stacked(sp, umap, s, sizes, fallback=g)
        w, denom = unit_weights(s, sizes)
        frac = w / jnp.where(denom > 0, denom, 1.0)[None, :]
        acc = streaming_init(g)
        for i in range(k):
            ci = jax.tree.map(lambda l: l[i], sp)
            acc = streaming_add(acc, ci, umap, frac[i])
        out = streaming_finalize(acc, umap, denom, g)
        for x, y in zip(jax.tree.leaves(stacked), jax.tree.leaves(out)):
            np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------------------
class TestComm:
    """Uses the paper's real VGG-9 (4.7M params) so the divergence-feedback
    vector is, as in the paper, negligible against layer payloads."""

    @pytest.fixture(scope="class")
    def vgg_umap(self):
        from repro.models import cnn
        params = cnn.init_params(jax.random.PRNGKey(0), cnn.VGGConfig())
        return UnitMap.build(params)

    def test_80_percent_savings(self, vgg_umap):
        """Paper headline: n/K = 0.2 -> ~80 % uplink reduction."""
        umap = vgg_umap
        k, n = 20, 4
        s = sel.topn_divergence(
            jax.random.uniform(jax.random.PRNGKey(0), (k, umap.num_units)), n)
        stats = round_comm(s, umap)
        assert abs(float(stats["savings_frac"]) - 0.8) < 0.01
        assert float(stats["uplink_payload"]) == pytest.approx(
            n * umap.total_bytes)

    def test_feedback_overhead_is_small(self, vgg_umap):
        umap = vgg_umap
        s = sel.full_participation(20, umap.num_units)
        stats = round_comm(s, umap, divergence_feedback=True)
        assert float(stats["uplink_feedback"]) == 20 * umap.num_units * 4
        assert float(stats["uplink_feedback"]) < 0.01 * float(
            stats["uplink_payload"])

    def test_payload_plus_feedback_is_total(self, vgg_umap):
        """The accounting invariant every consumer of the metrics dict
        relies on: uplink_payload + uplink_feedback == uplink_total."""
        umap = vgg_umap
        s = sel.topn_divergence(
            jax.random.uniform(jax.random.PRNGKey(1), (20, umap.num_units)),
            4)
        for fb in (False, True):
            stats = round_comm(s, umap, divergence_feedback=fb)
            assert float(stats["uplink_payload"]) \
                + float(stats["uplink_feedback"]) \
                == pytest.approx(float(stats["uplink_total"]))
            assert float(stats["savings_frac"]) == pytest.approx(
                1.0 - float(stats["uplink_total"])
                / float(stats["fedavg_uplink"]))


# ----------------------------------------------------------------------
class TestConvergenceBound:
    P = BoundParams(beta=1.0, xi1=0.1, xi2=0.05, grad_bound=1.0,
                    eta=0.05, num_layers=9, n=4, k=20)

    def test_n_equals_k_vanishes(self):
        p = conv.BoundParams(**{**self.P.__dict__, "n": 20})
        assert contraction_A(p) == 0.0
        assert asymptotic_gap(p) == 0.0

    def test_gap_decreases_in_n(self):
        gaps = [asymptotic_gap(conv.BoundParams(
            **{**self.P.__dict__, "n": n})) for n in range(1, 21)]
        assert all(g1 >= g2 - 1e-12 for g1, g2 in zip(gaps, gaps[1:]))

    def test_condition(self):
        assert conv.converges(self.P)
        bad = conv.BoundParams(**{**self.P.__dict__, "xi2": 1e6})
        assert not conv.converges(bad)

    def test_recursion_matches_closed_form(self):
        p, gap0 = self.P, 0.3
        a, b = contraction_A(p), conv.offset_B(p)
        gap = gap0
        for t in range(1, 6):
            gap = a * gap + b
            assert conv.gap_bound(p, t, gap0) == pytest.approx(gap, rel=1e-9)
