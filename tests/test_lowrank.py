"""Low-rank delta upload (beyond-paper, FedPara-adjacent)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lowrank import _lowrank_approx, lowrank_bytes, lowrank_upload
from repro.models import cnn


def test_exact_when_rank_suffices():
    """A true rank-3 matrix is recovered exactly at rank ≥ 3."""
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (40, 3))
    v = jax.random.normal(jax.random.PRNGKey(1), (3, 50))
    m = u @ v
    approx = _lowrank_approx(m, rank=3, iters=3)
    np.testing.assert_allclose(approx, m, rtol=1e-4, atol=1e-4)


def test_approx_error_decreases_with_rank():
    key = jax.random.PRNGKey(2)
    m = jax.random.normal(key, (64, 64))
    errs = []
    for r in (2, 8, 32, 64):
        a = _lowrank_approx(m, rank=r, iters=3)
        errs.append(float(jnp.linalg.norm(m - a)))
    assert errs[0] > errs[1] > errs[2]
    assert errs[3] < 1e-3  # full rank ⇒ exact


def test_upload_roundtrip_and_residual():
    cfg = cnn.VGGConfig().reduced()
    g = cnn.init_params(jax.random.PRNGKey(0), cfg)
    local = jax.tree.map(
        lambda l: l + 0.01 * jax.random.normal(jax.random.PRNGKey(1),
                                               l.shape), g)
    theta_hat, res = lowrank_upload(local, g, rank=4)
    # residual + reconstruction = true delta
    for t, l_, gg, r in zip(jax.tree.leaves(theta_hat),
                            jax.tree.leaves(local),
                            jax.tree.leaves(g), jax.tree.leaves(res)):
        np.testing.assert_allclose(np.asarray(t - gg) + np.asarray(r),
                                   np.asarray(l_ - gg), atol=1e-5)


def test_error_feedback_reduces_truncation_bias():
    """EF makes the compressor's *cumulative* sent messages track the true
    cumulative delta (compressor contraction δ = r/min(m,n) ⇒ need enough
    rounds relative to 1/δ for a visible gap)."""
    key = jax.random.PRNGKey(3)
    g = {"w": jnp.zeros((48, 48))}
    local = {"w": jax.random.normal(key, (48, 48))}
    true_delta = local["w"] - g["w"]
    rounds, rank = 12, 8
    sent_ef = jnp.zeros_like(true_delta)
    res = None
    for _ in range(rounds):
        th, res = lowrank_upload(local, g, rank=rank, residual=res)
        sent_ef += th["w"] - g["w"]
    err_ef = float(jnp.linalg.norm(sent_ef - rounds * true_delta))
    th0, _ = lowrank_upload(local, g, rank=rank)
    err_nef = float(jnp.linalg.norm(
        rounds * (th0["w"] - g["w"]) - rounds * true_delta))
    assert err_ef < err_nef * 0.8


def test_bytes_model():
    cfg = cnn.VGGConfig()
    g = cnn.init_params(jax.random.PRNGKey(0), cfg)
    full = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(g))
    lr = lowrank_bytes(g, rank=8)
    assert lr < 0.3 * full  # big compression on conv/fc matrices
    assert lr > 0           # and the dense small leaves still counted
