"""Low-rank delta upload (beyond-paper, FedPara-adjacent)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lowrank import _lowrank_approx, lowrank_bytes, lowrank_upload
from repro.models import cnn


def test_exact_when_rank_suffices():
    """A true rank-3 matrix is recovered exactly at rank ≥ 3."""
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (40, 3))
    v = jax.random.normal(jax.random.PRNGKey(1), (3, 50))
    m = u @ v
    approx = _lowrank_approx(m, rank=3, iters=3)
    np.testing.assert_allclose(approx, m, rtol=1e-4, atol=1e-4)


def test_approx_error_decreases_with_rank():
    key = jax.random.PRNGKey(2)
    m = jax.random.normal(key, (64, 64))
    errs = []
    for r in (2, 8, 32, 64):
        a = _lowrank_approx(m, rank=r, iters=3)
        errs.append(float(jnp.linalg.norm(m - a)))
    assert errs[0] > errs[1] > errs[2]
    assert errs[3] < 1e-3  # full rank ⇒ exact


def test_key_threading_default_matches_legacy_sketch():
    """key=None must be bit-compatible with the old fixed-PRNGKey(0) start."""
    m = jax.random.normal(jax.random.PRNGKey(4), (48, 40))
    legacy = _lowrank_approx(m, rank=5, iters=2)  # default key=None
    keyed = _lowrank_approx(m, rank=5, iters=2, key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(keyed))


def test_key_threading_quality_unchanged_on_fixed_seeds():
    """A threaded key changes the sketch, not the truncation quality."""
    u = jax.random.normal(jax.random.PRNGKey(5), (40, 3))
    v = jax.random.normal(jax.random.PRNGKey(6), (3, 50))
    m = u @ v
    for s in (7, 8, 9):   # exact recovery for any sketch seed
        a = _lowrank_approx(m, rank=3, iters=3, key=jax.random.PRNGKey(s))
        np.testing.assert_allclose(a, m, rtol=1e-4, atol=1e-4)
    full = jax.random.normal(jax.random.PRNGKey(10), (64, 64))
    base = float(jnp.linalg.norm(full - _lowrank_approx(full, 8, iters=3)))
    for s in (11, 12):
        e = float(jnp.linalg.norm(full - _lowrank_approx(
            full, 8, iters=3, key=jax.random.PRNGKey(s))))
        assert abs(e - base) < 0.2 * base


def test_upload_key_is_deterministic_and_decorrelates():
    g = {"w": jnp.zeros((48, 48)), "s": jnp.zeros((48, 2, 40, 40))}
    local = jax.tree.map(
        lambda l: jax.random.normal(jax.random.PRNGKey(13), l.shape), g)
    k = jax.random.PRNGKey(14)
    th1, r1 = lowrank_upload(local, g, rank=2, key=k)
    th2, r2 = lowrank_upload(local, g, rank=2, key=k)
    for a, b in zip(jax.tree.leaves(th1), jax.tree.leaves(th2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    th3, _ = lowrank_upload(local, g, rank=2, key=jax.random.PRNGKey(15))
    assert not np.array_equal(np.asarray(th1["w"]), np.asarray(th3["w"]))
    # residual identity holds under any key
    for t, l_, gg, r in zip(jax.tree.leaves(th1), jax.tree.leaves(local),
                            jax.tree.leaves(g), jax.tree.leaves(r1)):
        np.testing.assert_allclose(np.asarray(t - gg) + np.asarray(r),
                                   np.asarray(l_ - gg), atol=1e-5)


def test_upload_roundtrip_and_residual():
    cfg = cnn.VGGConfig().reduced()
    g = cnn.init_params(jax.random.PRNGKey(0), cfg)
    local = jax.tree.map(
        lambda l: l + 0.01 * jax.random.normal(jax.random.PRNGKey(1),
                                               l.shape), g)
    theta_hat, res = lowrank_upload(local, g, rank=4)
    # residual + reconstruction = true delta
    for t, l_, gg, r in zip(jax.tree.leaves(theta_hat),
                            jax.tree.leaves(local),
                            jax.tree.leaves(g), jax.tree.leaves(res)):
        np.testing.assert_allclose(np.asarray(t - gg) + np.asarray(r),
                                   np.asarray(l_ - gg), atol=1e-5)


def test_error_feedback_reduces_truncation_bias():
    """EF makes the compressor's *cumulative* sent messages track the true
    cumulative delta (compressor contraction δ = r/min(m,n) ⇒ need enough
    rounds relative to 1/δ for a visible gap)."""
    key = jax.random.PRNGKey(3)
    g = {"w": jnp.zeros((48, 48))}
    local = {"w": jax.random.normal(key, (48, 48))}
    true_delta = local["w"] - g["w"]
    rounds, rank = 12, 8
    sent_ef = jnp.zeros_like(true_delta)
    res = None
    for _ in range(rounds):
        th, res = lowrank_upload(local, g, rank=rank, residual=res)
        sent_ef += th["w"] - g["w"]
    err_ef = float(jnp.linalg.norm(sent_ef - rounds * true_delta))
    th0, _ = lowrank_upload(local, g, rank=rank)
    err_nef = float(jnp.linalg.norm(
        rounds * (th0["w"] - g["w"]) - rounds * true_delta))
    assert err_ef < err_nef * 0.8


def test_bytes_model():
    cfg = cnn.VGGConfig()
    g = cnn.init_params(jax.random.PRNGKey(0), cfg)
    full = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(g))
    lr = lowrank_bytes(g, rank=8)
    assert lr < 0.3 * full  # big compression on conv/fc matrices
    assert lr > 0           # and the dense small leaves still counted
