"""Auto-sharding policy: divisibility fallbacks, Megatron/FSDP defaults."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.shapes import SHAPES, adapt_config, build_program, params_struct


class FakeMesh:
    """Shape-only mesh stand-in (sharding policy is pure shape logic)."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


from repro.launch import sharding as sh  # noqa: E402


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_auto_spec_2d_mlp():
    spec = sh.auto_spec((5120, 13824), MESH)
    assert spec == P("data", "model")


def test_auto_spec_skip_leading():
    spec = sh.auto_spec((48, 5120, 13824), MESH, skip_leading=True)
    assert spec == P(None, "data", "model")


def test_auto_spec_indivisible_falls_back():
    # 25 heads × 64 = 1600 divides 16; 25 alone would not
    assert sh.auto_spec((1600, 25), MESH) == P("model", None)
    # fully indivisible -> replicate
    assert sh.auto_spec((7, 9), MESH) == P(None, None)


def test_auto_spec_model_only():
    """model_only: the FL round engine's policy — no data-axis factor, so
    a ('clients', 'model') mesh never shards params over 'clients'."""
    assert sh.auto_spec((5120, 13824), MESH, model_only=True) == \
        P(None, "model")
    fl_mesh = FakeMesh({"clients": 4, "model": 2})
    assert sh.auto_spec((3072, 16), fl_mesh, model_only=True) == \
        P("model", None)
    assert sh.auto_spec((48, 5120, 13824), fl_mesh, skip_leading=True,
                        model_only=True) == P(None, None, "model")


def test_auto_spec_multipod_uses_pod_axis():
    spec = sh.auto_spec((5120, 8192), MESH_MP)
    assert spec == P(("pod", "data"), "model")


def test_param_specs_structure():
    cfg = get_config("qwen3-1.7b")
    ps = params_struct(cfg)
    specs = sh.param_specs(ps, MESH)
    # stacked block leaves skip depth dim
    assert specs["blocks"]["mlp"]["w_up"][0] is None
    assert "model" in jax.tree.leaves(
        specs["blocks"]["mlp"]["w_up"], is_leaf=lambda x: True)[0] or True
    assert specs["blocks"]["mlp"]["w_up"] == P(None, "data", "model")
    # 1-D leaves replicated
    assert specs["final"]["norm"] == P()
    # embedding vocab-sharded
    assert specs["embed"]["tok"] == P("model", "data")


def test_param_specs_overrides():
    cfg = get_config("qwen3-1.7b")
    ps = params_struct(cfg)
    specs = sh.param_specs(ps, MESH, overrides={r"embed/tok": P(None, "model")})
    assert specs["embed"]["tok"] == P(None, "model")
    assert specs["blocks"]["mlp"]["w_up"] == P(None, "data", "model")


def test_batch_specs():
    b = {"tokens": jax.ShapeDtypeStruct((8, 32, 4096), jnp.int32)}
    specs = sh.batch_specs(b, MESH, client_leading=True)
    assert specs["tokens"] == P(None, "data", None)
    b2 = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    assert sh.batch_specs(b2, MESH)["tokens"] == P()


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_all_programs_build(arch, shape):
    """Every (arch × shape) produces a Program with consistent specs
    (lowering itself is exercised by the dry-run process)."""
    spec = SHAPES[shape]
    cfg = adapt_config(get_config(arch), spec)
    prog = build_program(cfg, spec)
    assert len(prog.args) == len(prog.arg_kinds)
    if spec.name == "long_500k" and cfg.family != "ssm":
        assert cfg.sliding_window > 0  # sub-quadratic enforced
    # every arg leaf is a ShapeDtypeStruct (no allocation)
    for leaf in jax.tree.leaves(prog.args):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
