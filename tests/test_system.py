"""End-to-end system behaviour: the public API as a user drives it.

Covers: FL training of the paper's VGG-9 (reduced) with all algorithms on a
non-IID split; the paper's §III-A configuration; LLM-arch FL round in scan
mode; serving round-trip through checkpointing.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_config, vgg9_fl
from repro.core.units import UnitMap
from repro.data import FederatedData, dirichlet_partition, make_image_dataset
from repro.federated import FLConfig, run_training
from repro.models import cnn, decode, transformer as tf

CFG = cnn.VGGConfig().reduced()


def _loss(params, batch):
    return cnn.classify_loss(params, CFG, batch)


@pytest.fixture(scope="module")
def fed_setup():
    train, test = make_image_dataset(num_train=1500, num_test=300, seed=2)
    parts = dirichlet_partition(train.ys, 10, alpha=1.0, seed=0)
    data = FederatedData(train.xs, train.ys, parts)
    test_batch = {"images": jnp.asarray(test.xs),
                  "labels": jnp.asarray(test.ys)}
    eval_fn = jax.jit(lambda p: 1.0 - cnn.accuracy(p, CFG, test_batch))
    return data, eval_fn


@pytest.mark.parametrize("algo", ["fedldf", "fedavg", "random", "hdfl",
                                  "fedadp"])
def test_all_algorithms_train(fed_setup, algo):
    data, eval_fn = fed_setup
    fl = FLConfig(algo=algo, num_clients=10, clients_per_round=5, top_n=2,
                  lr=0.08, mode="vmap", batch_per_client=16,
                  fedadp_keep=0.4)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    params, log = run_training(params, _loss, data, fl, rounds=6,
                               eval_fn=eval_fn, eval_every=5, seed=0)
    assert all(np.isfinite(l) for l in log.losses)
    err = log.test_errors[-1][1]
    assert 0.0 <= err <= 1.0
    if algo in ("fedldf", "random"):
        assert log.meter.savings_frac > 0.5


def test_paper_fl_config_matches_section_III():
    fl = vgg9_fl()
    assert (fl.num_clients, fl.clients_per_round, fl.top_n) == (50, 20, 4)
    assert fl.algo == "fedldf"
    # 1 - n/K = 0.8 -> the 80 % headline
    assert 1 - fl.top_n / fl.clients_per_round == pytest.approx(0.8)


def test_llm_arch_fl_round_scan():
    """FedLDF round on a reduced LLM arch (the large-model code path)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              param_dtype="float32",
                              compute_dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    umap = UnitMap.build(params)
    from repro.federated import build_round_scan
    fl = FLConfig(algo="fedldf", clients_per_round=3, top_n=1, mode="scan",
                  lr=0.01)
    loss_fn = functools.partial(lambda c, p, b: tf.lm_loss(p, c, b), cfg)
    round_fn = jax.jit(build_round_scan(loss_fn, umap, fl))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (3, 2, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (3, 2, 16), 0, cfg.vocab_size)}
    new_params, metrics = round_fn(params, batch, jnp.ones((3,)),
                                   jax.random.PRNGKey(2))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["comm"]["savings_frac"]) > 0.6


def test_checkpoint_then_serve(tmp_path):
    """Global model -> checkpoint -> reload -> decode: identical logits."""
    import dataclasses, os
    cfg = dataclasses.replace(get_config("qwen2-vl-2b").reduced(),
                              param_dtype="float32",
                              compute_dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "global.npz")
    save_pytree(path, params)
    loaded = load_pytree(path)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    lg1, c1 = decode.prefill(params, cfg, toks, max_len=14)
    lg2, c2 = decode.prefill(loaded, cfg, toks, max_len=14)
    np.testing.assert_allclose(lg1, lg2, atol=1e-6)
    s1, _ = decode.decode_step(params, cfg, toks[:, :1], c1)
    s2, _ = decode.decode_step(loaded, cfg, toks[:, :1], c2)
    np.testing.assert_allclose(s1, s2, atol=1e-6)
