"""Public-surface contract for the two user-facing packages.

``repro.core`` and ``repro.federated`` declare an explicit ``__all__``:
everything listed must resolve, nothing listed may be private, and the
wire/compression API introduced with the packed uplink must be reachable
from both roots (``CompressionConfig`` is the shared config seam).
"""
import dataclasses
import importlib
import inspect

import pytest

PACKAGES = ["repro.core", "repro.federated"]


@pytest.mark.parametrize("pkg", PACKAGES)
def test_all_names_resolve(pkg):
    mod = importlib.import_module(pkg)
    assert isinstance(mod.__all__, list) and mod.__all__
    for name in mod.__all__:
        assert getattr(mod, name, None) is not None, \
            f"{pkg}.__all__ lists {name!r} but it does not resolve"


@pytest.mark.parametrize("pkg", PACKAGES)
def test_no_private_leakage(pkg):
    mod = importlib.import_module(pkg)
    leaked = [n for n in mod.__all__ if n.startswith("_")]
    assert not leaked, f"{pkg}.__all__ exports private names: {leaked}"
    dupes = [n for n in mod.__all__ if mod.__all__.count(n) > 1]
    assert not dupes, f"{pkg}.__all__ lists duplicates: {dupes}"


@pytest.mark.parametrize("pkg", PACKAGES)
def test_star_import_matches_all(pkg):
    ns = {}
    exec(f"from {pkg} import *", ns)  # noqa: S102 - the contract under test
    ns.pop("__builtins__", None)
    mod = importlib.import_module(pkg)
    assert set(ns) == set(mod.__all__)


def test_wire_api_reachable_from_both_roots():
    import repro.core as core
    import repro.federated as fed
    # one class, re-exported at both seams
    assert fed.CompressionConfig is core.CompressionConfig
    assert dataclasses.is_dataclass(core.CompressionConfig)
    assert dataclasses.is_dataclass(core.PackedPayload)
    assert isinstance(core.UNIT_HEADER_BYTES, int)
    assert callable(core.allocate_bits)


def test_strategy_options_exported():
    import repro.federated as fed
    for name in ("FedADPOptions", "FedLPOptions", "FedLAMAOptions"):
        cls = getattr(fed, name)
        assert dataclasses.is_dataclass(cls), name
        cls()  # defaults construct
    assert inspect.isclass(fed.QuantizedUpload)


def test_algos_registry_view_live():
    import repro.federated as fed
    algos = fed.ALGOS
    for name in ("fedldf", "fedavg", "fedadp", "fedlp", "fedlama"):
        assert name in algos
