"""Trainable-partition seam: split/merge semantics, partition=None
bit-identity across all drivers, frozen-base invariance, driver
equivalence under a partition, compression composition, and the adapter
workload's uplink cut."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import (ParamPartition, leaf_paths,
                                  partition_counts)
from repro.data import (FederatedData, iid_partition, lm_federated,
                        make_image_dataset, make_lm_dataset)
from repro.federated import (CompressionConfig, FLConfig, run_training,
                             run_training_scan)
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.lora import inject_lora, lora_partition


def _mlp_params(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return {
        "l1": {"w": jax.random.normal(ks[0], (192, 16)) * 0.02,
               "b": jnp.zeros((16,))},
        "head": {"w": jax.random.normal(ks[1], (16, 10)) * 0.1,
                 "b": jnp.zeros((10,))},
    }


def _loss(params, batch):
    x = batch["images"].reshape(batch["images"].shape[0], -1)
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    logits = h @ params["head"]["w"] + params["head"]["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, batch["labels"][:, None],
                                axis=-1).mean()


@pytest.fixture(scope="module")
def fed_data():
    train, _ = make_image_dataset(num_train=160, num_test=16, size=8,
                                  seed=1)
    parts = iid_partition(train.ys, 8, seed=0)
    return FederatedData(train.xs, train.ys, parts)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------------------------
# ParamPartition semantics
# ----------------------------------------------------------------------
def test_split_merge_roundtrip():
    params = _mlp_params()
    part = ParamPartition.by_keys(params, ["head"])
    trainable, frozen = part.split(params)
    assert set(trainable) == {"head"} and set(frozen) == {"l1"}
    _assert_trees_equal(part.merge(trainable, frozen), params)
    # by_substring: path-segment match, not substring-anywhere
    part2 = ParamPartition.by_substring(params, "head")
    assert part2.trainable_paths == part.trainable_paths


def test_partition_validation_errors():
    params = _mlp_params()
    with pytest.raises(KeyError):
        ParamPartition.by_keys(params, ["nope"])
    with pytest.raises(ValueError, match="at least one trainable"):
        ParamPartition.by_substring(params, "nomatch")
    with pytest.raises(ValueError, match="both trainable and frozen"):
        ParamPartition(trainable_paths=("head/w",),
                       frozen_paths=("head/w", "head/b"))
    part = ParamPartition.by_keys(params, ["head"])
    with pytest.raises(ValueError):    # unclassified leaves
        part.split({**params, "extra": {"w": jnp.zeros((2,))}})
    with pytest.raises(TypeError):
        ParamPartition.build(jnp.zeros((3,)), lambda p, l: True)


def test_partition_counts_and_paths():
    params = _mlp_params()
    part = ParamPartition.by_keys(params, ["head"])
    c = partition_counts(part, params)
    assert c["trainable_params"] == 16 * 10 + 10
    assert c["frozen_params"] == 192 * 16 + 16
    assert c["trainable_bytes"] == 4 * c["trainable_params"]
    paths = dict(leaf_paths(params))
    assert set(paths) == {"l1/w", "l1/b", "head/w", "head/b"}


def test_flconfig_rejects_non_partition():
    with pytest.raises(TypeError, match="partition"):
        FLConfig(algo="fedldf", clients_per_round=4, partition="head")


# ----------------------------------------------------------------------
# partition=None bit-identity (the refactor's core contract)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("algo", ["fedldf", "fedavg"])
def test_all_trainable_partition_is_bit_identical_to_none(fed_data, algo):
    """partition=None and an all-trainable partition must produce the SAME
    trajectory bitwise, in every driver — the seam may not perturb the
    unpartitioned engine."""
    params = _mlp_params()
    full = ParamPartition.by_keys(params, ["head", "l1"])
    kw = dict(algo=algo, num_clients=8, clients_per_round=4, top_n=2,
              batch_per_client=8)
    for runner, extra in ((run_training, {"sampler": "jax"}),
                          (run_training_scan, {})):
        p0, l0 = runner(params, _loss, fed_data, FLConfig(**kw),
                        rounds=3, seed=3, **extra)
        pF, lF = runner(params, _loss, fed_data,
                        FLConfig(partition=full, **kw),
                        rounds=3, seed=3, **extra)
        _assert_trees_equal(p0, pF)
        assert l0.losses == lF.losses
    # sequential-clients scan engine
    p0, _ = run_training_scan(params, _loss, fed_data,
                              FLConfig(mode="scan", **kw), rounds=3, seed=3)
    pF, _ = run_training_scan(params, _loss, fed_data,
                              FLConfig(mode="scan", partition=full, **kw),
                              rounds=3, seed=3)
    _assert_trees_equal(p0, pF)


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs 2 devices")
def test_all_trainable_partition_is_bit_identical_to_none_mesh(fed_data):
    from repro.launch.mesh import make_client_mesh
    params = _mlp_params()
    full = ParamPartition.by_keys(params, ["head", "l1"])
    kw = dict(algo="fedldf", num_clients=8, clients_per_round=4, top_n=2,
              batch_per_client=8, mesh=make_client_mesh(2))
    p0, _ = run_training(params, _loss, fed_data, FLConfig(**kw),
                         rounds=3, seed=3, sampler="jax")
    pF, _ = run_training(params, _loss, fed_data,
                         FLConfig(partition=full, **kw),
                         rounds=3, seed=3, sampler="jax")
    _assert_trees_equal(p0, pF)


# ----------------------------------------------------------------------
# Partitioned training: frozen invariance + driver equivalence
# ----------------------------------------------------------------------
def test_partitioned_frozen_stays_frozen_and_drivers_agree(fed_data):
    params = _mlp_params()
    part = ParamPartition.by_keys(params, ["head"])
    kw = dict(algo="fedldf", num_clients=8, clients_per_round=4, top_n=1,
              batch_per_client=8, partition=part)
    ph, lh = run_training(params, _loss, fed_data, FLConfig(**kw),
                          rounds=3, seed=3, sampler="jax")
    ps, _ = run_training_scan(params, _loss, fed_data, FLConfig(**kw),
                              rounds=3, seed=3)
    # frozen leaves bitwise untouched; trainable leaves moved
    _assert_trees_equal(ph["l1"], params["l1"])
    assert not np.array_equal(np.asarray(ph["head"]["w"]),
                              np.asarray(params["head"]["w"]))
    for a, b in zip(jax.tree.leaves(ph), jax.tree.leaves(ps)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-6)
    # sequential-clients engine agrees too
    pq, _ = run_training_scan(params, _loss, fed_data,
                              FLConfig(mode="scan", **kw), rounds=3, seed=3)
    for a, b in zip(jax.tree.leaves(ph), jax.tree.leaves(pq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-6)
    # the ledger charges trainable bytes only: head = (16·10+10)·4 B
    per_round = lh.meter.fedavg_uplink_bytes / 3
    assert per_round == 4 * (16 * 10 + 10) * 4


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs 2 devices")
def test_partitioned_mesh_matches_flat(fed_data):
    from repro.launch.mesh import make_client_mesh
    params = _mlp_params()
    part = ParamPartition.by_keys(params, ["head"])
    kw = dict(algo="fedldf", num_clients=8, clients_per_round=4, top_n=1,
              batch_per_client=8, partition=part)
    ph, _ = run_training(params, _loss, fed_data, FLConfig(**kw),
                         rounds=3, seed=3, sampler="jax")
    pm, _ = run_training(params, _loss, fed_data,
                         FLConfig(mesh=make_client_mesh(2), **kw),
                         rounds=3, seed=3, sampler="jax")
    _assert_trees_equal(ph["l1"], pm["l1"])
    for a, b in zip(jax.tree.leaves(ph), jax.tree.leaves(pm)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_partition_composes_with_packed_compression(fed_data):
    params = _mlp_params()
    part = ParamPartition.by_keys(params, ["head"])
    fl = FLConfig(algo="fedldf", num_clients=8, clients_per_round=4,
                  top_n=1, batch_per_client=8, partition=part,
                  compression=CompressionConfig(bits=8,
                                                error_feedback=True))
    pc, lc = run_training(params, _loss, fed_data, fl, rounds=3, seed=3,
                          sampler="jax")
    _assert_trees_equal(pc["l1"], params["l1"])
    # packed int8 uplink of the trainable subset is below its fp32 bytes
    assert lc.meter.uplink_bytes < lc.meter.fedavg_uplink_bytes


# ----------------------------------------------------------------------
# Adapter workload: the acceptance-number check
# ----------------------------------------------------------------------
def test_lora_adapter_uplink_at_least_10x_below_full_model():
    cfg = ModelConfig(name="tiny", family="dense", d_model=64,
                      num_layers=2, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128, param_dtype="float32",
                      compute_dtype="float32")
    tokens, domains = make_lm_dataset(num_sequences=64, seq_len=17,
                                      vocab=128, num_domains=4, seed=0)
    data = lm_federated(tokens, domains, 4)
    params = inject_lora(jax.random.PRNGKey(1),
                         tfm.init_params(jax.random.PRNGKey(0), cfg),
                         rank=2)
    part = lora_partition(params)
    fl = FLConfig(algo="fedavg", num_clients=4, clients_per_round=2,
                  top_n=1, batch_per_client=4, partition=part)
    trained, log = run_training(params, tfm.make_lm_loss(cfg), data, fl,
                                rounds=2, seed=0, sampler="jax")
    full_bytes = sum(l.size * l.dtype.itemsize
                     for l in jax.tree.leaves(params))
    full_up = full_bytes * 2                 # K=2 clients, full model
    adapter_up = log.meter.uplink_bytes / 2  # per round
    assert adapter_up * 10 <= full_up
    # the frozen transformer base is returned bitwise intact
    _, frozen0 = part.split(params)
    _, frozenT = part.split(trained)
    _assert_trees_equal(frozen0, frozenT)


def test_telemetry_meta_records_partition(fed_data, tmp_path):
    from repro.federated import TelemetryConfig
    import json
    params = _mlp_params()
    part = ParamPartition.by_keys(params, ["head"])
    led = str(tmp_path / "ledger.jsonl")
    fl = FLConfig(algo="fedldf", num_clients=8, clients_per_round=4,
                  top_n=1, batch_per_client=8, partition=part,
                  telemetry=TelemetryConfig(ledger_path=led))
    run_training(params, _loss, fed_data, fl, rounds=2, seed=0,
                 sampler="jax")
    run_rec = [json.loads(l) for l in open(led)
               if json.loads(l).get("kind") == "run"][0]
    assert run_rec["units"] == ["head"]        # trainable-subset units only
    assert run_rec["partition"]["trainable_params"] == 16 * 10 + 10
    assert run_rec["partition"]["frozen_params"] == 192 * 16 + 16
