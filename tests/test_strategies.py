"""Strategy-plugin seams: registry round-trip, capability flags, FedADP
vmap-vs-scan equivalence, FedLP end-to-end, and the per-strategy
comm_profile ledger invariant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.federated as fed
from repro.core import selection as sel
from repro.core.units import UnitMap
from repro.data import FederatedData, iid_partition, make_image_dataset
from repro.federated import (FLConfig, FLStrategy, build_round_fn,
                             make_strategy, register_strategy,
                             registered_algos, run_training,
                             run_training_scan, unregister_strategy)
from repro.models import cnn

CFG = cnn.VGGConfig().reduced()
BUILTINS = ("fedldf", "fedavg", "random", "hdfl", "fedadp", "fedlp")
ALL_ALGOS = BUILTINS + ("fedlama",)


def _loss(params, batch):
    return cnn.classify_loss(params, CFG, batch)


@pytest.fixture(scope="module")
def setup():
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    umap = UnitMap.build(params)
    k = 6
    key = jax.random.PRNGKey(3)
    batch = {"images": jax.random.normal(key, (k, 8, 32, 32, 3)),
             "labels": jax.random.randint(key, (k, 8), 0, 10)}
    sizes = jnp.array([10.0, 20.0, 30.0, 10.0, 15.0, 25.0])
    return params, umap, batch, sizes, key, k


@pytest.fixture(scope="module")
def fed_data():
    train, _ = make_image_dataset(num_train=400, num_test=40, seed=1)
    parts = iid_partition(train.ys, 8, seed=0)
    return FederatedData(train.xs, train.ys, parts)


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------
def test_builtins_registered_in_order():
    algos = registered_algos()
    assert algos[:len(BUILTINS)] == BUILTINS
    assert fed.ALGOS == algos          # live module-level view


def test_unknown_algo_lists_registered_names():
    with pytest.raises(ValueError) as ei:
        FLConfig(algo="definitely-not-registered")
    msg = str(ei.value)
    for name in BUILTINS:
        assert name in msg


def test_register_round_trip(fed_data):
    """register → FLConfig resolves → a real training run → unregister."""

    @register_strategy("first_n")
    class FirstN(FLStrategy):
        """Deterministic toy policy: clients 0..n-1 upload everything."""

        def select(self, divs, key, k, u, n):
            rows = (jnp.arange(k) < n).astype(jnp.float32)
            return jnp.broadcast_to(rows[:, None], (k, u))

    try:
        assert "first_n" in fed.ALGOS
        fl = FLConfig(algo="first_n", num_clients=8, clients_per_round=4,
                      top_n=2, lr=0.05, batch_per_client=8)
        params = cnn.init_params(jax.random.PRNGKey(0), CFG)
        params, log = run_training(params, _loss, fed_data, fl, rounds=2,
                                   seed=0)
        assert all(np.isfinite(l) for l in log.losses)
        # n/K = 1/2 of the payload, no divergence feedback
        assert log.meter.savings_frac == pytest.approx(0.5, abs=1e-6)
    finally:
        unregister_strategy("first_n")
    assert "first_n" not in fed.ALGOS
    with pytest.raises(ValueError):
        FLConfig(algo="first_n")


def test_register_duplicate_name_guarded():
    """A plugin can't silently replace a builtin (or another plugin)."""
    with pytest.raises(ValueError, match="already registered"):
        @register_strategy("fedavg")
        class Impostor(FLStrategy):
            def select(self, divs, key, k, u, n):
                return jnp.zeros((k, u))
    from repro.federated.strategies import get_strategy_cls
    fedavg_cls = get_strategy_cls("fedavg")
    # same class, same name: idempotent (module re-import)
    assert register_strategy("fedavg")(fedavg_cls) is fedavg_cls
    # explicit override is allowed — and restorable
    try:
        @register_strategy("fedavg", override=True)
        class Replacement(FLStrategy):
            def select(self, divs, key, k, u, n):
                return jnp.ones((k, u))
        assert get_strategy_cls("fedavg") is Replacement
    finally:
        register_strategy("fedavg", override=True)(fedavg_cls)
    assert get_strategy_cls("fedavg") is fedavg_cls


def test_reregistered_strategy_misses_stale_jit_cache(fed_data):
    """The driver's compiled-callable cache must not hand a re-registered
    name the round compiled for the previously registered class."""
    p0 = cnn.init_params(jax.random.PRNGKey(0), CFG)

    def run_once():
        fl = FLConfig(algo="tmpstrat", num_clients=8, clients_per_round=4,
                      top_n=2, lr=0.05, batch_per_client=8)
        _, log = run_training(p0, _loss, fed_data, fl, rounds=1, seed=0)
        return log.meter.savings_frac

    @register_strategy("tmpstrat")
    class AllLayers(FLStrategy):
        def select(self, divs, key, k, u, n):
            return jnp.ones((k, u), jnp.float32)

    try:
        assert run_once() == pytest.approx(0.0, abs=1e-6)
        unregister_strategy("tmpstrat")

        @register_strategy("tmpstrat")
        class HalfClients(FLStrategy):
            def select(self, divs, key, k, u, n):
                rows = (jnp.arange(k) < k // 2).astype(jnp.float32)
                return jnp.broadcast_to(rows[:, None], (k, u))

        # identical FLConfig: a stale cache would reproduce 0.0 savings
        assert run_once() == pytest.approx(0.5, abs=1e-6)
    finally:
        unregister_strategy("tmpstrat")


# ----------------------------------------------------------------------
# Capability flags
# ----------------------------------------------------------------------
def test_capability_flags_validated():
    with pytest.raises(ValueError, match="supports_quantize"):
        FLConfig(algo="fedadp", quantize_bits=8)
    with pytest.raises(NotImplementedError):
        FLConfig(algo="fedldf", mode="scan", quantize_bits=8)
    # fedadp in scan mode is now a declared capability, not an assert
    assert FLConfig(algo="fedadp", mode="scan").algo == "fedadp"


@pytest.mark.skipif(len(jax.devices()) < 1, reason="needs a device")
def test_fedadp_mesh_capability_flipped():
    """fedadp now ships psum_parts/psum_finalize overrides, so a mesh
    config validates (the equivalence matrix lives in
    tests/test_shard_engine.py)."""
    from repro.launch.mesh import make_client_mesh
    mesh = make_client_mesh(1)
    fl = FLConfig(algo="fedadp", clients_per_round=4, top_n=2, mesh=mesh)
    assert type(make_strategy(fl)).supports_mesh


# ----------------------------------------------------------------------
# FedADP scan mode (unlocked by the refactor)
# ----------------------------------------------------------------------
def test_fedadp_vmap_scan_trajectory_equivalence(fed_data):
    """Multi-round driver equivalence on a fixed seed: the scan engine
    stacks sequentially-trained locals into the same aggregate hook."""
    kw = dict(algo="fedadp", num_clients=8, clients_per_round=4, top_n=2,
              lr=0.05, batch_per_client=8, fedadp_keep=0.3)
    p0 = cnn.init_params(jax.random.PRNGKey(0), CFG)
    pv, lv = run_training(p0, _loss, fed_data,
                          FLConfig(mode="vmap", **kw), rounds=3, seed=0,
                          sampler="jax")
    ps, ls = run_training(p0, _loss, fed_data,
                          FLConfig(mode="scan", **kw), rounds=3, seed=0,
                          sampler="jax")
    for a, b in zip(jax.tree.leaves(pv), jax.tree.leaves(ps)):
        np.testing.assert_allclose(a, b, atol=3e-5)
    np.testing.assert_allclose(lv.losses, ls.losses, atol=1e-4)
    assert lv.meter.uplink_bytes == pytest.approx(ls.meter.uplink_bytes)


# ----------------------------------------------------------------------
# FedLP
# ----------------------------------------------------------------------
def test_fedlp_selection_is_bernoulli(setup):
    params, umap, batch, sizes, key, k = setup
    s = sel.bernoulli_per_layer(key, 50, umap.num_units, 0.5)
    assert s.shape == (50, umap.num_units)
    assert set(np.unique(np.asarray(s))) <= {0.0, 1.0}
    assert 0.3 < float(s.mean()) < 0.7
    with pytest.raises(ValueError):
        sel.bernoulli_per_layer(key, 4, 3, 0.0)


def test_fedlp_round_and_comm(setup):
    """One fedlp round: Eq. 5 over the Bernoulli mask; uplink ≈ p·FedAvg
    plus the keep-mask header, and the ledger invariant holds."""
    params, umap, batch, sizes, key, k = setup
    fl = FLConfig(algo="fedlp", clients_per_round=k, top_n=2, fedlp_p=0.5)
    p, m = jax.jit(build_round_fn(_loss, umap, fl))(params, batch, sizes,
                                                    key)
    assert np.isfinite(float(m["loss"]))
    c = m["comm"]
    assert float(c["uplink_payload"]) + float(c["uplink_feedback"]) == \
        pytest.approx(float(c["uplink_total"]))
    sel_frac = float(np.asarray(m["selection"]).mean())
    assert float(c["uplink_payload"]) <= float(c["fedavg_uplink"])
    # payload tracks the realised keep mask (unit sizes vary, so compare
    # against the mask-weighted bytes, not the raw fraction)
    expect = float((np.asarray(m["selection"])
                    * np.asarray(umap.unit_bytes_array())[None, :]).sum())
    assert float(c["uplink_payload"]) == pytest.approx(expect)
    mask_hdr = k * ((umap.num_units + 7) // 8)
    assert float(c["uplink_feedback"]) == pytest.approx(mask_hdr)
    assert 0.0 < sel_frac < 1.0


def test_fedlp_trains_end_to_end(fed_data):
    """FLConfig(algo='fedlp') through both multi-round drivers."""
    fl = FLConfig(algo="fedlp", num_clients=8, clients_per_round=4,
                  top_n=2, lr=0.05, batch_per_client=8, fedlp_p=0.5)
    p0 = cnn.init_params(jax.random.PRNGKey(0), CFG)
    ph, lh = run_training(p0, _loss, fed_data, fl, rounds=3, seed=0,
                          sampler="jax")
    ps, lscan = run_training_scan(p0, _loss, fed_data, fl, rounds=3, seed=0)
    assert all(np.isfinite(l) for l in lh.losses)
    for a, b in zip(jax.tree.leaves(ph), jax.tree.leaves(ps)):
        np.testing.assert_allclose(a, b, atol=2e-6)
    # ~p of FedAvg uplink (+ tiny mask header), Bernoulli-noisy
    assert 0.2 < lh.meter.savings_frac < 0.8


# ----------------------------------------------------------------------
# comm_profile ledger invariant — every registered strategy
# ----------------------------------------------------------------------
def _config_for(algo):
    return FLConfig(algo=algo, num_clients=50, clients_per_round=6,
                    top_n=2, fedadp_keep=0.3, fedlp_p=0.4)


@pytest.mark.parametrize("algo", ALL_ALGOS)
@pytest.mark.parametrize("quantized", [False, True])
def test_comm_profile_invariant(setup, algo, quantized):
    """payload + feedback == total, and savings_frac is consistent, for
    every registered strategy — bare and under the quantize wrapper.
    Selection goes through select_with_state (the engines' entry point),
    which exercises the stateless-delegation default and lets the
    stateful fedlama participate."""
    params, umap, batch, sizes, key, k = setup
    fl = _config_for(algo)
    if quantized:
        if not type(make_strategy(fl)).supports_quantize:
            pytest.skip(f"{algo} declares supports_quantize=False")
        fl = FLConfig(algo=algo, num_clients=50, clients_per_round=6,
                      top_n=2, fedadp_keep=0.3, fedlp_p=0.4,
                      quantize_bits=8)
    strat = make_strategy(fl)
    divs = (jax.random.uniform(key, (k, umap.num_units))
            if strat.needs_divergence else None)
    state = strat.init_state(params, fl.num_clients)
    s = strat.select_with_state(state, divs, key, k, umap.num_units,
                                fl.top_n)
    c = strat.comm_profile(s, umap)
    payload, feedback = float(c["uplink_payload"]), float(c["uplink_feedback"])
    total, ref = float(c["uplink_total"]), float(c["fedavg_uplink"])
    assert payload + feedback == pytest.approx(total), strat.name
    # abs tolerance: savings_frac is computed on-device in fp32, and for
    # near-zero savings (fedlama's round-0 full sync + feedback) the
    # default relative approx is tighter than fp32 resolution
    assert float(c["savings_frac"]) == pytest.approx(1.0 - total / ref,
                                                     abs=1e-6)
    assert float(c["downlink"]) == pytest.approx(ref)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
