"""Device-resident multi-round engine: host-oracle equivalence, device
sampling/gathering, and the error-feedback residual-threading regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (ClientShards, FederatedData, iid_partition,
                        make_image_dataset)
from repro.federated import (FLConfig, run_training, run_training_scan,
                             sample_clients_jax)

N_CLIENTS, K = 8, 4


def _mlp_params(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return {
        "l1": {"w": jax.random.normal(ks[0], (3072, 16)) * 0.02,
               "b": jnp.zeros((16,))},
        "head": {"w": jax.random.normal(ks[1], (16, 10)) * 0.1,
                 "b": jnp.zeros((10,))},
    }


def _loss(params, batch):
    x = batch["images"].reshape(batch["images"].shape[0], -1)
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    logits = h @ params["head"]["w"] + params["head"]["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, batch["labels"][:, None],
                                axis=-1).mean()


@pytest.fixture(scope="module")
def task():
    train, _ = make_image_dataset(num_train=320, num_test=16, seed=1)
    parts = iid_partition(train.ys, N_CLIENTS, seed=0)
    data = FederatedData(train.xs, train.ys, parts)
    return _mlp_params(), data


def _assert_trees_close(a, b, atol=2e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("algo", ["fedldf", "fedavg"])
@pytest.mark.parametrize("mode", ["vmap", "scan"])
def test_scan_engine_matches_host_driver(task, algo, mode):
    """Same seed ⇒ same trajectory: host loop (JAX sampler) vs scan engine,
    across aggregation algorithms and client-execution modes."""
    params, data = task
    fl = FLConfig(algo=algo, num_clients=N_CLIENTS, clients_per_round=K,
                  top_n=2, mode=mode, batch_per_client=8)
    ph, lh = run_training(params, _loss, data, fl, rounds=4, seed=3,
                          sampler="jax")
    ps, ls = run_training_scan(params, _loss, data, fl, rounds=4, seed=3)
    _assert_trees_close(ph, ps)
    np.testing.assert_allclose(lh.losses, ls.losses, atol=1e-5)
    assert lh.meter.uplink_bytes == pytest.approx(ls.meter.uplink_bytes)
    assert lh.meter.rounds == ls.meter.rounds == 4


def test_scan_engine_eval_blocks_match_host(task):
    """Eval chunking must not perturb the trajectory, and eval points must
    mirror the host driver's (t % eval_every == 0 or last)."""
    params, data = task
    fl = FLConfig(algo="fedldf", num_clients=N_CLIENTS, clients_per_round=K,
                  top_n=2, mode="vmap", batch_per_client=8)
    eval_fn = jax.jit(lambda p: jnp.float32(0.5))
    ph, lh = run_training(params, _loss, data, fl, rounds=5, seed=0,
                          sampler="jax", eval_fn=eval_fn, eval_every=2)
    ps, ls = run_training_scan(params, _loss, data, fl, rounds=5, seed=0,
                               eval_fn=eval_fn, eval_every=2)
    _assert_trees_close(ph, ps)
    assert [t for t, _, _ in lh.test_errors] == \
        [t for t, _, _ in ls.test_errors]


# ----------------------------------------------------------------------
class TestHostKeySchedule:
    """Regression for the host-sampler key schedule: the old
    ``PRNGKey(seed * 100003 + t)`` degenerated to ``key = t`` at seed=0 and
    let nearby seeds replay each other's per-round keys once the round
    index crossed the stride (seed s, round t ≡ seed s+1, round t-100003).
    The fix folds the round index into one per-seed base key."""

    @staticmethod
    def _key(seed, t):
        return tuple(np.asarray(
            jax.random.fold_in(jax.random.PRNGKey(seed), t)).tolist())

    def test_streams_disjoint_across_seeds(self):
        # include the adversarial pair that collided under the old scheme:
        # (seed=0, t=100003) vs (seed=1, t=0)
        rounds = [0, 1, 2, 100003, 100004]
        streams = {s: {self._key(s, t) for t in rounds} for s in range(4)}
        for s1 in streams:
            for s2 in streams:
                if s1 < s2:
                    assert not (streams[s1] & streams[s2]), (s1, s2)

    def test_seed_zero_not_degenerate(self):
        # old schedule: seed=0, round t  ->  PRNGKey(t) exactly
        for t in range(4):
            assert self._key(0, t) != tuple(
                np.asarray(jax.random.PRNGKey(t)).tolist())

    def test_host_random_algo_differs_across_seeds(self, task):
        """Driver-level: key-driven selection policies must see different
        streams for different seeds from round 0 on."""
        params, data = task
        fl = FLConfig(algo="random", num_clients=N_CLIENTS,
                      clients_per_round=K, top_n=2, mode="vmap",
                      batch_per_client=8)
        _, l0 = run_training(params, _loss, data, fl, rounds=2, seed=0)
        _, l1 = run_training(params, _loss, data, fl, rounds=2, seed=1)
        assert l0.losses != l1.losses


# ----------------------------------------------------------------------
class TestDeviceSampling:
    def test_sample_clients_jax_distinct_in_range(self):
        for s in range(5):
            c = np.asarray(sample_clients_jax(jax.random.PRNGKey(s), 10, 6))
            assert len(np.unique(c)) == 6
            assert c.min() >= 0 and c.max() < 10

    def test_gather_deterministic_and_within_partition(self, task):
        _, data = task
        shards = ClientShards.from_federated(data)
        clients = jnp.array([1, 3, 5])
        key = jax.random.PRNGKey(7)
        b1 = shards.gather(clients, 4, key)
        b2 = shards.gather(clients, 4, key)
        np.testing.assert_array_equal(np.asarray(b1["images"]),
                                      np.asarray(b2["images"]))
        # every gathered sample must come from the owning client's shard
        sizes = shards.part_sizes
        j = jax.random.randint(key, (3, 4), 0, sizes[clients][:, None])
        gidx = np.asarray(shards.part_idx[clients[:, None], j])
        for row, c in enumerate([1, 3, 5]):
            assert set(gidx[row]) <= set(np.asarray(data.parts[c]))

    def test_shards_pad_unequal_partitions(self):
        xs = np.arange(40, dtype=np.float32).reshape(10, 2, 2)
        ys = np.arange(10)
        parts = [np.array([0, 1, 2, 3, 4, 5]), np.array([6, 7]),
                 np.array([8, 9])]
        shards = ClientShards.from_federated(FederatedData(xs, ys, parts))
        assert shards.part_idx.shape == (3, 6)
        np.testing.assert_array_equal(np.asarray(shards.part_sizes),
                                      [6, 2, 2])
        # cyclic padding keeps every slot a valid member of the shard
        for i, p in enumerate(parts):
            assert set(np.asarray(shards.part_idx[i])) == set(p)

    def test_gather_small_shard_respects_padding_contract(self):
        """The cyclic-pad contract: a client whose shard is smaller than
        ``batch_per_client`` must never sample an index beyond
        ``part_sizes[c]`` — every gathered sample belongs to the owning
        client's true partition, with a batch much larger than the shard,
        and the whole (small) shard is reachable across keys."""
        xs = np.arange(10, dtype=np.float32)[:, None]   # value == global idx
        ys = np.arange(10)
        parts = [np.arange(6), np.array([6, 7]), np.array([8, 9])]
        shards = ClientShards.from_federated(FederatedData(xs, ys, parts))
        batch = 16                                      # >> shard sizes 2
        seen = {1: set(), 2: set()}
        for s in range(10):
            b = shards.gather(jnp.array([1, 2]), batch,
                              jax.random.PRNGKey(s))
            got = np.asarray(b["labels"])               # global sample ids
            assert got.shape == (2, batch)
            assert set(got[0]) <= {6, 7}, "client 1 sampled out of shard"
            assert set(got[1]) <= {8, 9}, "client 2 sampled out of shard"
            seen[1] |= set(got[0].tolist())
            seen[2] |= set(got[1].tolist())
        assert seen[1] == {6, 7} and seen[2] == {8, 9}


# ----------------------------------------------------------------------
class TestMixedDtypeErrorFeedback:
    """Residual-store dtype: the store must mirror each leaf's own dtype
    (a hard-coded float32 store silently upcast EF arithmetic — and
    doubled the store's memory — for bf16/fp16 params)."""

    @staticmethod
    def _mixed_params():
        p = _mlp_params()
        p["head"] = jax.tree.map(lambda l: l.astype(jnp.bfloat16),
                                 p["head"])
        return p

    def test_store_dtypes_mirror_leaves(self):
        from repro.federated import init_residual_store
        p = self._mixed_params()
        store = init_residual_store(p, N_CLIENTS)
        for leaf, row in zip(jax.tree.leaves(p), jax.tree.leaves(store)):
            assert row.dtype == leaf.dtype
            assert row.shape == (N_CLIENTS,) + leaf.shape

    def test_mixed_dtype_ef_trains_and_drivers_agree(self, task):
        _, data = task
        p = self._mixed_params()
        fl = FLConfig(algo="fedldf", num_clients=N_CLIENTS,
                      clients_per_round=K, top_n=2, mode="vmap",
                      batch_per_client=8, quantize_bits=4,
                      error_feedback=True)
        ph, lh = run_training(p, _loss, data, fl, rounds=3, seed=0,
                              sampler="jax")
        ps, ls = run_training_scan(p, _loss, data, fl, rounds=3, seed=0)
        # dtypes preserved through rounds, trajectories agree, loss finite
        for a, b in zip(jax.tree.leaves(ph), jax.tree.leaves(ps)):
            assert a.dtype == b.dtype
            np.testing.assert_allclose(
                np.asarray(a, dtype=np.float32),
                np.asarray(b, dtype=np.float32), atol=2e-5)
        assert ph["head"]["w"].dtype == jnp.bfloat16
        assert all(np.isfinite(lh.losses)) and all(np.isfinite(ls.losses))


# ----------------------------------------------------------------------
class TestErrorFeedback:
    """Regression for the silent no-op: residuals must be threaded through
    rounds, so EF changes the uploaded payloads from round 2 onward."""

    def _cfg(self, ef):
        return FLConfig(algo="fedldf", num_clients=N_CLIENTS,
                        clients_per_round=K, top_n=2, mode="vmap",
                        batch_per_client=8, quantize_bits=4,
                        error_feedback=ef)

    def test_round_one_identical_then_diverges(self, task):
        params, data = task
        # residuals are zero in round 1 ⇒ EF cannot change the payload yet
        p_off1, _ = run_training_scan(params, _loss, data, self._cfg(False),
                                      rounds=1, seed=0)
        p_on1, _ = run_training_scan(params, _loss, data, self._cfg(True),
                                     rounds=1, seed=0)
        _assert_trees_close(p_off1, p_on1, atol=0.0)
        # from round 2 the carried residual alters Q(Δ+e) — uploads differ
        p_off, _ = run_training_scan(params, _loss, data, self._cfg(False),
                                     rounds=3, seed=0)
        p_on, _ = run_training_scan(params, _loss, data, self._cfg(True),
                                    rounds=3, seed=0)
        diff = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(p_off), jax.tree.leaves(p_on)))
        assert diff > 1e-6, "error feedback had no effect across rounds"

    def test_host_driver_threads_residuals_too(self, task):
        """The host driver fix: run_training must agree with the engine
        when error feedback is on (it used to drop the residuals)."""
        params, data = task
        ph, _ = run_training(params, _loss, data, self._cfg(True),
                             rounds=3, seed=0, sampler="jax")
        ps, _ = run_training_scan(params, _loss, data, self._cfg(True),
                                  rounds=3, seed=0)
        _assert_trees_close(ph, ps)
