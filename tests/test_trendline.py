"""CI perf-trendline logic (benchmarks/trendline.py): metric extraction
from BENCH_ci.json dumps, the windowed-median baseline, and the fail-soft
regression comparison."""
import json

import pytest

from benchmarks.trendline import (WINDOW, compare, extract, main,
                                  median_baseline)

BENCH = {
    "ci": True,
    "kernel": {"rows": [["divergence_jnp", 1.0, "x"]],
               "uplink_fused_speedup": 2.0},
    "engine": {"mode": "floor", "host_rate": 50.0, "scan_rate": 200.0,
               "speedup": 4.0},
    "shard": {"unsharded": 40.0, "speedup": 1.5,
              "mesh": {"1": 35.0, "2": 45.0, "8": 60.0},
              "model_mesh": {"model": 2, "rate": 30.0},
              "equiv_ok": True},
}


def test_extract_flattens_tracked_metrics():
    got = extract(BENCH)
    assert got["engine.scan_rate"] == 200.0
    assert got["shard.speedup"] == 1.5
    assert got["shard.mesh.8"] == 60.0
    assert got["shard.model_mesh.rate"] == 30.0
    assert got["kernel.uplink_fused_speedup"] == 2.0
    assert "ci" not in got


def test_extract_tolerates_missing_sections():
    assert extract({}) == {}
    assert extract({"engine": {"scan_rate": 1.0}}) == {
        "engine.scan_rate": 1.0}
    # non-numeric junk is skipped, not crashed on
    assert extract({"shard": {"speedup": "n/a", "mesh": {"2": None}}}) == {}


def test_extract_tolerates_pre_wire_kernel_list():
    # pre-wire BENCH_ci artifacts stored [kernel] as a CSV row list; old
    # history in the trendline window must not crash the gate
    old = {"kernel": [["divergence_jnp", 1.0, "x"]],
           "engine": {"scan_rate": 5.0}}
    assert extract(old) == {"engine.scan_rate": 5.0}


def test_compare_flags_only_large_drops():
    prev = extract(BENCH)
    curr = dict(prev)
    curr["engine.scan_rate"] = 150.0          # -25 %: regression
    curr["shard.speedup"] = 1.35              # -10 %: within noise
    regressions, lines = compare(prev, curr, threshold=0.2)
    assert len(regressions) == 1
    assert "engine.scan_rate" in regressions[0]
    assert any("shard.speedup" in line for line in lines)


def test_compare_improvements_and_disjoint_keys_ok():
    regs, _ = compare({"a": 1.0}, {"a": 2.0})       # improvement
    assert regs == []
    regs, lines = compare({"a": 1.0}, {"b": 1.0})   # nothing in common
    assert regs == []
    assert any("(new)" in line for line in lines) and \
        any("(gone)" in line for line in lines)


def test_median_baseline_resists_one_noisy_runner():
    """One inflated (or deflated) run in the window no longer IS the
    baseline: the median of the last runs absorbs it."""
    steady = {"engine.scan_rate": 100.0}
    inflated = {"engine.scan_rate": 300.0}    # noisy-fast runner
    baseline = median_baseline([steady, steady, inflated])
    assert baseline["engine.scan_rate"] == 100.0
    # a healthy current run is NOT flagged against the inflated outlier
    regs, _ = compare(baseline, {"engine.scan_rate": 95.0}, threshold=0.2)
    assert regs == []
    # ...and a deflated outlier can't mask a real regression
    deflated = {"engine.scan_rate": 10.0}
    baseline = median_baseline([steady, steady, deflated])
    regs, _ = compare(baseline, {"engine.scan_rate": 50.0}, threshold=0.2)
    assert len(regs) == 1


def test_median_baseline_window_and_partial_metrics():
    # only the last WINDOW runs count (old history dropped from the front)
    runs = [{"m": 1.0}] * 10 + [{"m": 5.0}] * WINDOW
    assert median_baseline(runs)["m"] == 5.0
    # a metric present in just one run is still tracked
    got = median_baseline([{"a": 1.0}, {"a": 3.0, "b": 7.0}])
    assert got == {"a": 2.0, "b": 7.0}


def test_main_multiple_prev_median(tmp_path, capsys):
    """--prev is repeatable; the gate compares against the median, and
    unreadable files in the list are skipped individually."""
    paths = []
    for i, rate in enumerate((200.0, 210.0, 1000.0)):   # one noisy outlier
        p = tmp_path / f"prev{i}.json"
        p.write_text(json.dumps({"engine": {"scan_rate": rate}}))
        paths.append(str(p))
    paths.append(str(tmp_path / "missing.json"))
    curr = tmp_path / "curr.json"
    curr.write_text(json.dumps({"engine": {"scan_rate": 195.0}}))
    argv = []
    for p in paths:
        argv += ["--prev", p]
    # median 210 -> 195 is -7%: within noise despite the 1000.0 outlier
    assert main(argv + ["--curr", str(curr), "--strict"]) == 0
    out = capsys.readouterr().out
    assert "skipping unreadable" in out
    assert "median of last 3" in out


def test_main_fail_soft_vs_strict(tmp_path, capsys):
    prev, curr = tmp_path / "prev.json", tmp_path / "curr.json"
    prev.write_text(json.dumps(BENCH))
    bad = {"engine": {"scan_rate": 100.0}}          # -50 % vs 200
    curr.write_text(json.dumps(bad))
    assert main(["--prev", str(prev), "--curr", str(curr)]) == 0
    assert "::warning" in capsys.readouterr().out
    assert main(["--prev", str(prev), "--curr", str(curr),
                 "--strict"]) == 1


def test_main_missing_previous_artifact_skips(tmp_path, capsys):
    curr = tmp_path / "curr.json"
    curr.write_text(json.dumps(BENCH))
    assert main(["--prev", str(tmp_path / "nope.json"),
                 "--curr", str(curr)]) == 0
    assert "skipping diff" in capsys.readouterr().out


def test_no_regression_exit_zero(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps(BENCH))
    assert main(["--prev", str(p), "--curr", str(p), "--strict"]) == 0


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
