"""CI perf-trendline logic (benchmarks/trendline.py): metric extraction
from BENCH_ci.json dumps and the fail-soft regression comparison."""
import json

import pytest

from benchmarks.trendline import compare, extract, main

BENCH = {
    "ci": True,
    "engine": {"mode": "floor", "host_rate": 50.0, "scan_rate": 200.0,
               "speedup": 4.0},
    "shard": {"unsharded": 40.0, "speedup": 1.5,
              "mesh": {"1": 35.0, "2": 45.0, "8": 60.0},
              "model_mesh": {"model": 2, "rate": 30.0},
              "equiv_ok": True},
}


def test_extract_flattens_tracked_metrics():
    got = extract(BENCH)
    assert got["engine.scan_rate"] == 200.0
    assert got["shard.speedup"] == 1.5
    assert got["shard.mesh.8"] == 60.0
    assert got["shard.model_mesh.rate"] == 30.0
    assert "ci" not in got


def test_extract_tolerates_missing_sections():
    assert extract({}) == {}
    assert extract({"engine": {"scan_rate": 1.0}}) == {
        "engine.scan_rate": 1.0}
    # non-numeric junk is skipped, not crashed on
    assert extract({"shard": {"speedup": "n/a", "mesh": {"2": None}}}) == {}


def test_compare_flags_only_large_drops():
    prev = extract(BENCH)
    curr = dict(prev)
    curr["engine.scan_rate"] = 150.0          # -25 %: regression
    curr["shard.speedup"] = 1.35              # -10 %: within noise
    regressions, lines = compare(prev, curr, threshold=0.2)
    assert len(regressions) == 1
    assert "engine.scan_rate" in regressions[0]
    assert any("shard.speedup" in line for line in lines)


def test_compare_improvements_and_disjoint_keys_ok():
    regs, _ = compare({"a": 1.0}, {"a": 2.0})       # improvement
    assert regs == []
    regs, lines = compare({"a": 1.0}, {"b": 1.0})   # nothing in common
    assert regs == []
    assert any("(new)" in line for line in lines) and \
        any("(gone)" in line for line in lines)


def test_main_fail_soft_vs_strict(tmp_path, capsys):
    prev, curr = tmp_path / "prev.json", tmp_path / "curr.json"
    prev.write_text(json.dumps(BENCH))
    bad = {"engine": {"scan_rate": 100.0}}          # -50 % vs 200
    curr.write_text(json.dumps(bad))
    assert main(["--prev", str(prev), "--curr", str(curr)]) == 0
    assert "::warning" in capsys.readouterr().out
    assert main(["--prev", str(prev), "--curr", str(curr),
                 "--strict"]) == 1


def test_main_missing_previous_artifact_skips(tmp_path, capsys):
    curr = tmp_path / "curr.json"
    curr.write_text(json.dumps(BENCH))
    assert main(["--prev", str(tmp_path / "nope.json"),
                 "--curr", str(curr)]) == 0
    assert "skipping diff" in capsys.readouterr().out


def test_no_regression_exit_zero(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps(BENCH))
    assert main(["--prev", str(p), "--curr", str(p), "--strict"]) == 0


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
