"""Substrate layers: optimizers, checkpointing, data pipeline, SSD oracle."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.data import make_image_dataset, make_lm_dataset, lm_federated
from repro.optim import adamw, sgd


# ----------------------------------------------------------------------
class TestOptim:
    def _quad(self, params):
        return jnp.sum((params["w"] - 3.0) ** 2)

    @pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.1, momentum=0.9),
                                     adamw(0.3)],
                             ids=["sgd", "sgd-mom", "adamw"])
    def test_converges_on_quadratic(self, opt):
        params = {"w": jnp.zeros((4,))}
        state = opt.init(params)
        for _ in range(100):
            g = jax.grad(self._quad)(params)
            params, state = opt.update(g, state, params)
        np.testing.assert_allclose(params["w"], 3.0, atol=0.05)

    def test_sgd_step_exact(self):
        opt = sgd(0.5)
        params = {"w": jnp.array([1.0])}
        g = {"w": jnp.array([2.0])}
        new, _ = opt.update(g, opt.init(params), params)
        assert float(new["w"][0]) == pytest.approx(0.0)

    def test_weight_decay(self):
        opt = sgd(0.1, weight_decay=0.1)
        params = {"w": jnp.array([1.0])}
        g = {"w": jnp.array([0.0])}
        new, _ = opt.update(g, opt.init(params), params)
        assert float(new["w"][0]) < 1.0


# ----------------------------------------------------------------------
class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": {"b": np.arange(6).reshape(2, 3).astype(np.float32)},
                "c": np.float32(2.5) * np.ones((4,))}
        path = os.path.join(tmp_path, "ckpt.npz")
        save_pytree(path, tree)
        loaded = load_pytree(path)
        np.testing.assert_array_equal(loaded["a"]["b"], tree["a"]["b"])
        np.testing.assert_array_equal(loaded["c"], tree["c"])

    def test_roundtrip_model_params(self, tmp_path):
        from repro.models import cnn
        cfg = cnn.VGGConfig().reduced()
        params = cnn.init_params(jax.random.PRNGKey(0), cfg)
        path = os.path.join(tmp_path, "model.npz")
        save_pytree(path, params)
        loaded = load_pytree(path)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), b)


# ----------------------------------------------------------------------
class TestData:
    def test_image_dataset_learnable_structure(self):
        train, test = make_image_dataset(num_train=500, num_test=100, seed=0)
        assert train.xs.shape == (500, 32, 32, 3)
        assert set(np.unique(train.ys)) <= set(range(10))
        # class-conditional structure: same-class images correlate more
        c0 = train.xs[train.ys == 0][:10].reshape(-1, 32 * 32 * 3)
        c1 = train.xs[train.ys == 1][:10].reshape(-1, 32 * 32 * 3)
        intra = np.corrcoef(c0)[np.triu_indices(len(c0), 1)].mean()
        inter = np.corrcoef(np.vstack([c0[:5], c1[:5]]))[:5, 5:].mean()
        assert intra > inter + 0.05

    def test_lm_dataset_and_federation(self):
        toks, domains = make_lm_dataset(num_sequences=64, seq_len=32,
                                        vocab=128, num_domains=4, seed=0)
        assert toks.shape == (64, 32) and toks.max() < 128
        fed = lm_federated(toks, domains, num_clients=8)
        assert fed.num_clients == 8
        batch = fed.round_batch(np.array([0, 3]), 4,
                                np.random.default_rng(0))
        assert batch["tokens"].shape == (2, 4, 31)
        assert batch["labels"].shape == (2, 4, 31)


# ----------------------------------------------------------------------
class TestSSDOracle:
    """Chunked SSD == naive per-step recurrence (the mathematical ground
    truth of the state-space duality)."""

    def test_ssd_matches_naive_recurrence(self):
        from repro.models.config import ModelConfig
        from repro.models import ssm
        cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                          num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=10,
                          ssm_state=8, ssm_head_dim=16, ssm_chunk=8)
        p = ssm.init_ssm(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, 32))
        out_chunked = ssm.ssd_fwd(p, x, cfg)
        # naive: run decode step token by token
        cache = ssm.init_ssm_cache(cfg, 2)
        outs = []
        for t in range(20):
            o, cache = ssm.ssd_step(p, x[:, t:t + 1], cache, cfg)
            outs.append(o)
        out_naive = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(out_chunked, out_naive, rtol=2e-3,
                                   atol=2e-4)
