"""Model-axis (FSDP) sharding: 2-D ('clients', 'model') mesh equivalence.

The tentpole property: a round engine run on a 2-D mesh — params and the
error-feedback residual store held as 1/M 'model'-axis shards per device —
must reproduce the single-device trajectory to the same fp32 tolerance the
1-D client mesh is pinned to (the all_gather/slice round trip is pure data
movement; only the clients-axis psum changes fp32 reduction order). On top
of trajectory equality, the leaves must *actually* be sharded: per-device
bytes shrink ~1/M for every divisible leaf.

Needs forced host devices (``REPRO_TEST_DEVICES=8``); multi-device cases
skip cleanly on a plain single-device run, and the pure spec-policy tests
(:func:`fl_param_specs`) run anywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from benchmarks.round_engine_bench import EQUIV_TOL
from repro.data import FederatedData, iid_partition, make_image_dataset
from repro.federated import (FLConfig, init_residual_store,
                             residual_store_specs, run_training,
                             run_training_scan)
from repro.launch.mesh import (make_client_mesh, model_mesh_size,
                               replicated_rng)
from repro.launch.sharding import fl_param_specs

N_CLIENTS, K = 8, 4
ATOL = EQUIV_TOL

# (clients, model) mesh factorisations; total devices = clients * model
MESHES_2D = [
    pytest.param(c, m, marks=pytest.mark.skipif(
        len(jax.devices()) < c * m,
        reason=f"needs {c * m} devices; set REPRO_TEST_DEVICES=8"))
    for c, m in [(1, 2), (2, 2), (2, 4), (4, 2)]
]


def _mlp_params(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    return {
        "l1": {"w": jax.random.normal(ks[0], (3072, 16)) * 0.02,
               "b": jnp.zeros((16,))},
        "head": {"w": jax.random.normal(ks[1], (16, 10)) * 0.1,
                 "b": jnp.zeros((10,))},
    }


def _loss(params, batch):
    x = batch["images"].reshape(batch["images"].shape[0], -1)
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    logits = h @ params["head"]["w"] + params["head"]["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, batch["labels"][:, None],
                                axis=-1).mean()


@pytest.fixture(scope="module")
def task():
    train, _ = make_image_dataset(num_train=320, num_test=16, seed=1)
    parts = iid_partition(train.ys, N_CLIENTS, seed=0)
    data = FederatedData(train.xs, train.ys, parts)
    return _mlp_params(), data


def _assert_trees_close(a, b, atol=ATOL):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol)


def _cfg(mesh, algo="fedldf", **kw):
    return FLConfig(algo=algo, num_clients=N_CLIENTS, clients_per_round=K,
                    top_n=2, mode="vmap", batch_per_client=8, mesh=mesh,
                    **kw)


# ----------------------------------------------------------------------
# Trajectory equivalence (acceptance criterion: fedldf + fedavg, with and
# without EF, on a 2-D mesh vs the single-device path, fixed seeds).
# ----------------------------------------------------------------------
@pytest.mark.parametrize("algo", ["fedldf", "fedavg"])
@pytest.mark.parametrize("clients,model", MESHES_2D)
def test_2d_mesh_matches_unsharded(task, algo, clients, model):
    params, data = task
    p0, l0 = run_training_scan(params, _loss, data, _cfg(None, algo),
                               rounds=4, seed=3)
    mesh = make_client_mesh(clients * model, model=model)
    p1, l1 = run_training_scan(params, _loss, data, _cfg(mesh, algo),
                               rounds=4, seed=3)
    _assert_trees_close(p0, p1)
    np.testing.assert_allclose(l0.losses, l1.losses, atol=ATOL)
    assert l0.meter.uplink_bytes == pytest.approx(l1.meter.uplink_bytes)
    assert l0.meter.downlink_bytes == pytest.approx(l1.meter.downlink_bytes)


@pytest.mark.parametrize("clients,model", MESHES_2D)
def test_2d_mesh_error_feedback(task, clients, model):
    """EF residual rows flow 'model'-sharded through gather/round/scatter
    and must reproduce the unsharded EF trajectory — and EF must keep its
    cross-round effect under model sharding."""
    params, data = task

    def efcfg(mesh, ef):
        return _cfg(mesh, quantize_bits=4, error_feedback=ef)

    mesh = make_client_mesh(clients * model, model=model)
    p0, _ = run_training_scan(params, _loss, data, efcfg(None, True),
                              rounds=3, seed=0)
    p1, _ = run_training_scan(params, _loss, data, efcfg(mesh, True),
                              rounds=3, seed=0)
    _assert_trees_close(p0, p1)
    p_off, _ = run_training_scan(params, _loss, data, efcfg(mesh, False),
                                 rounds=3, seed=0)
    diff = max(float(jnp.abs(a - b).max()) for a, b in
               zip(jax.tree.leaves(p1), jax.tree.leaves(p_off)))
    assert diff > 1e-6, "error feedback lost its effect under model sharding"


@pytest.mark.parametrize("clients,model", MESHES_2D)
def test_2d_mesh_quantized_no_ef(task, clients, model):
    params, data = task
    p0, l0 = run_training_scan(params, _loss, data,
                               _cfg(None, quantize_bits=4), rounds=2, seed=0)
    p1, l1 = run_training_scan(params, _loss, data,
                               _cfg(make_client_mesh(clients * model,
                                                     model=model),
                                    quantize_bits=4), rounds=2, seed=0)
    _assert_trees_close(p0, p1)
    assert l0.meter.uplink_bytes == pytest.approx(l1.meter.uplink_bytes)


@pytest.mark.parametrize("clients,model", MESHES_2D)
def test_2d_host_driver_matches_engine(task, clients, model):
    params, data = task
    mesh = make_client_mesh(clients * model, model=model)
    ph, lh = run_training(params, _loss, data, _cfg(mesh), rounds=3, seed=0,
                          sampler="jax")
    ps, ls = run_training_scan(params, _loss, data, _cfg(mesh), rounds=3,
                               seed=0)
    _assert_trees_close(ph, ps)
    assert lh.meter.uplink_bytes == pytest.approx(ls.meter.uplink_bytes)


# ----------------------------------------------------------------------
# The memory claim: leaves are *actually* model-sharded — per-device bytes
# shrink ~1/M for params and for the residual store.
# ----------------------------------------------------------------------
@pytest.mark.parametrize("clients,model", MESHES_2D)
def test_param_leaves_carry_model_shards(task, clients, model):
    params, data = task
    mesh = make_client_mesh(clients * model, model=model)
    p1, _ = run_training_scan(params, _loss, data, _cfg(mesh), rounds=2,
                              seed=0)
    # the engine's returned params keep the FSDP layout: the big (3072, 16)
    # leaf is split 1/M along dim 0 on every device
    w = p1["l1"]["w"]
    shard = w.addressable_shards[0].data
    assert shard.shape == (3072 // model, 16)
    assert shard.nbytes == w.nbytes // model
    hw = p1["head"]["w"]                       # 16 % M == 0 for M in {2,4}
    assert hw.addressable_shards[0].data.shape == (16 // model, 10)
    # 1-D leaves are replicated (auto_spec falls back)
    b = p1["l1"]["b"]
    assert b.addressable_shards[0].data.shape == b.shape


@pytest.mark.parametrize("clients,model", MESHES_2D)
def test_residual_store_carries_model_shards(task, clients, model):
    """The EF store — the N × model-size memory cliff — must live 1/M
    'model'-sharded per device, client-id axis replicated."""
    params, _ = task
    mesh = make_client_mesh(clients * model, model=model)
    specs = residual_store_specs(params, mesh)
    assert specs["l1"]["w"] == P(None, "model", None)
    assert specs["l1"]["b"] == P(None)
    # created sharded (mesh arg): the full N× store never materialises
    # replicated on one device
    store = init_residual_store(params, N_CLIENTS, mesh)
    row = store["l1"]["w"]                     # (N, 3072, 16)
    shard = row.addressable_shards[0].data
    assert shard.shape == (N_CLIENTS, 3072 // model, 16)
    assert shard.nbytes == row.nbytes // model
    # store dtype mirrors the param leaf dtype (no silent fp32 upcast)
    assert row.dtype == params["l1"]["w"].dtype


# ----------------------------------------------------------------------
# Pure spec policy + mesh builders (no forced devices needed).
# ----------------------------------------------------------------------
class FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_fl_param_specs_model_only():
    params = jax.tree.map(lambda s: jnp.zeros(s),
                          {"l1": {"w": (3072, 16), "b": (16,)},
                           "head": {"w": (16, 10), "b": (10,)}},
                          is_leaf=lambda x: isinstance(x, tuple))
    mesh = FakeMesh({"clients": 2, "model": 2})
    specs = fl_param_specs(params, mesh)
    # largest divisible dim -> 'model'; nothing ever lands on 'clients'
    assert specs["l1"]["w"] == P("model", None)
    assert specs["head"]["w"] == P("model", None)
    assert specs["l1"]["b"] == P()
    assert "clients" not in [s for spec in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)) for s in spec]
    # 1-D client mesh (or model=1): everything replicated — the
    # byte-identical pre-model-axis layout
    for fake in (FakeMesh({"clients": 4}), FakeMesh({"clients": 4,
                                                     "model": 1})):
        assert all(s == P() for s in jax.tree.leaves(
            fl_param_specs(params, fake),
            is_leaf=lambda x: isinstance(x, P)))
    # indivisible leaf falls back to replication (all-None spec)
    odd = {"x": jnp.zeros((7, 9))}
    assert jax.tree.leaves(fl_param_specs(odd, mesh),
                           is_leaf=lambda x: isinstance(x, P))[0] \
        == P(None, None)


def test_fl_param_specs_never_shards_unit_axes():
    """Every stacked key the UnitMap treats as a unit axis — including
    'experts', which the dry-run policy's STACKED_TOPKEYS does not list —
    must keep its leading depth dim unsharded, or the per-unit aggregation
    epilogue breaks on 1/M slices."""
    mesh = FakeMesh({"clients": 2, "model": 2})
    params = {"blocks": {"w": jnp.zeros((2, 16, 16))},
              "experts": {"w": jnp.zeros((8, 6, 6))}}
    specs = fl_param_specs(params, mesh)
    assert specs["blocks"]["w"][0] is None
    # without the stacked_keys alignment this was P('model', None, None)
    assert specs["experts"]["w"] == P(None, None, "model")


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 devices")
def test_2d_mesh_stacked_units_model(task):
    """Stacked-key params (n > 1 units per span) through the 2-D mesh: the
    unit axis stays whole while trailing dims are model-sharded, and the
    trajectory matches the single-device engine."""
    _, data = task
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    params = {
        "embed": {"w": jax.random.normal(ks[0], (3072, 16)) * 0.02},
        "blocks": {"w": jax.random.normal(ks[1], (2, 16, 16)) * 0.1,
                   "b": jnp.zeros((2, 16))},
        "head": {"w": jax.random.normal(ks[2], (16, 10)) * 0.1},
    }

    def loss(p, batch):
        x = batch["images"].reshape(batch["images"].shape[0], -1)
        h = x @ p["embed"]["w"]
        for i in range(2):
            h = jax.nn.relu(h @ p["blocks"]["w"][i] + p["blocks"]["b"][i])
        logits = h @ p["head"]["w"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, batch["labels"][:, None],
                                    axis=-1).mean()

    p0, _ = run_training_scan(params, loss, data, _cfg(None), rounds=3,
                              seed=0)
    mesh = make_client_mesh(4, model=2)
    p1, _ = run_training_scan(params, loss, data, _cfg(mesh), rounds=3,
                              seed=0)
    _assert_trees_close(p0, p1)
    bw = p1["blocks"]["w"]                 # unit axis whole, dim2 sharded
    assert bw.addressable_shards[0].data.shape == (2, 16, 8)


def test_make_client_mesh_model_factor():
    if len(jax.devices()) >= 4:
        mesh = make_client_mesh(4, model=2)
        assert mesh.axis_names == ("clients", "model")
        assert mesh.shape["clients"] == 2 and mesh.shape["model"] == 2
        assert model_mesh_size(mesh) == 2
        with pytest.raises(AssertionError):   # K=5 not divisible by clients=2
            FLConfig(num_clients=10, clients_per_round=5, top_n=2, mesh=mesh)
    mesh1 = make_client_mesh(1)
    assert mesh1.axis_names == ("clients",)
    assert model_mesh_size(mesh1) == 1        # no 'model' axis -> 1
    if len(jax.devices()) >= 3:
        with pytest.raises(ValueError):       # model must divide the total
            make_client_mesh(3, model=2)


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 devices")
def test_replicated_rng_matches_single_device():
    """The engine's RNG guard: draws computed inside a replicated shard_map
    must be bit-identical to the eager single-device draw on any mesh (the
    non-partitionable threefry lowering silently changes values when XLA's
    partitioner shards it — the scan-engine regression this pins down)."""
    key = jax.random.PRNGKey(7)
    want = np.asarray(jax.random.randint(key, (4, 8), 0, 37))
    for model in (1, 2):
        mesh = make_client_mesh(4, model=model)
        got = jax.jit(replicated_rng(
            lambda k_: jax.random.randint(k_, (4, 8), 0, 37), mesh))(key)
        np.testing.assert_array_equal(want, np.asarray(got))
