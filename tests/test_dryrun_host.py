"""Dry-run pipeline integration on the single host device.

Exercises the full lower+compile path (program building, in/out shardings,
roofline extraction) on a 1×1 mesh with reduced configs — the 512-device
production pass runs in its own process (launch/dryrun.py); this test
guards the machinery itself in CI.
"""
import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.launch import hloparse
from repro.launch.mesh import make_host_mesh
from repro.launch.shapes import ShapeSpec, build_program, FL_TRAIN
from repro.launch.sharding import batch_specs, param_specs, to_named

SMALL_SHAPES = {
    "train": ShapeSpec("train_small", "train", 32, 8),
    "prefill": ShapeSpec("prefill_small", "prefill", 64, 2),
    "decode": ShapeSpec("decode_small", "decode", 64, 2),
}


def _reduced(arch):
    return dataclasses.replace(get_config(arch).reduced(),
                               param_dtype="float32",
                               compute_dtype="float32")


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-780m",
                                  "deepseek-moe-16b",
                                  "seamless-m4t-large-v2"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_lower_compile_and_roofline(arch, kind):
    cfg = _reduced(arch)
    shape = SMALL_SHAPES[kind]
    flcfg = dataclasses.replace(FL_TRAIN, clients_per_round=2, top_n=1)
    program = build_program(cfg, shape, flcfg)
    mesh = make_host_mesh(1, 1)
    with mesh:
        in_sh = []
        for arg, k in zip(program.args, program.arg_kinds):
            if k in ("params", "cache"):
                in_sh.append(to_named(param_specs(arg, mesh), mesh))
            elif k == "batch":
                in_sh.append(to_named(batch_specs(
                    arg, mesh, client_leading=program.flcfg is not None),
                    mesh))
            else:
                from jax.sharding import NamedSharding, PartitionSpec as P
                in_sh.append(jax.tree.map(
                    lambda _: NamedSharding(mesh, P()), arg))
        compiled = jax.jit(program.fn,
                           in_shardings=tuple(in_sh)).lower(
            *program.args).compile()
    totals = hloparse.analyze(compiled.as_text())
    assert totals.flops > 0
    assert totals.hbm_bytes > 0
    mem = compiled.memory_analysis()
    assert mem is None or mem.temp_size_in_bytes >= 0
