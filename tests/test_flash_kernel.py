"""Pallas flash-attention kernel vs oracle: shape/dtype/mask sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional [test] extra — deterministic fallbacks below
    HAVE_HYPOTHESIS = False

from repro.kernels.flash_attention import flash_attention, ref_attention

CASES = [
    # (bh, bkv, sq, skv, hd, causal, window, tq, tk)
    (4, 2, 64, 64, 32, True, 0, 16, 32),
    (2, 2, 100, 100, 32, True, 0, 32, 32),
    (6, 2, 48, 48, 16, True, 7, 16, 16),
    (2, 1, 33, 65, 64, False, 0, 16, 32),
    (8, 1, 40, 40, 128, True, 0, 8, 128),    # GQA group 8, MXU-width hd
]


@pytest.mark.parametrize("case", CASES, ids=[str(i) for i in range(len(CASES))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_flash_matches_oracle(case, dtype):
    bh, bkv, sq, skv, hd, causal, window, tq, tk = case
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (bh, sq, hd), dtype=dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (bkv, skv, hd), dtype=dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (bkv, skv, hd), dtype=dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          tq=tq, tk=tk, interpret=True)
    exp = ref_attention(q, k, v, causal=causal, window=window)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_tile_shape_invariance():
    """Output must not depend on the BlockSpec tiling."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 96, 32))
    k = jax.random.normal(jax.random.PRNGKey(4), (2, 96, 32))
    v = jax.random.normal(jax.random.PRNGKey(5), (2, 96, 32))
    outs = [flash_attention(q, k, v, tq=tq, tk=tk, interpret=True)
            for tq, tk in [(16, 16), (32, 48), (96, 96)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-6)


def test_matches_model_attend_path():
    """Kernel agrees with the model-level attend() used by the zoo."""
    from repro.models import attention as attn
    key = jax.random.PRNGKey(0)
    b, s, h, kvh, hd = 2, 64, 4, 2, 32
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, hd))
    pos = jnp.arange(s)
    model_out = attn.attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True)
    # kernel layout: (B·H, S, hd) with grouped q interleaved per kv head
    qg = q.reshape(b, s, kvh, h // kvh, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(b * h, s, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(b * kvh, s, hd)
    vv = v.transpose(0, 2, 1, 3).reshape(b * kvh, s, hd)
    kern = flash_attention(qg, kk, vv, causal=True, tq=16, tk=32,
                           interpret=True)
    kern = kern.reshape(b, kvh, h // kvh, s, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(b, s, h, hd)
    np.testing.assert_allclose(kern, model_out, rtol=2e-4, atol=2e-5)


def _check_flash_random_shapes(sq, skv, seed):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (2, sq, 16))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, skv, 16))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (2, skv, 16))
    out = flash_attention(q, k, v, causal=False, tq=16, tk=16,
                          interpret=True)
    exp = ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-5)


# deterministic fallback grid — covers the invariant without hypothesis
@pytest.mark.parametrize("sq,skv,seed", [
    (2, 2, 0), (40, 60, 1), (17, 33, 2), (16, 16, 3), (3, 47, 424242),
])
def test_flash_random_shapes_cases(sq, skv, seed):
    _check_flash_random_shapes(sq, skv, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(sq=st.integers(2, 40), skv=st.integers(2, 60),
           seed=st.integers(0, 10**6))
    def test_flash_property_random_shapes(sq, skv, seed):
        _check_flash_random_shapes(sq, skv, seed)
