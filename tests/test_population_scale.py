"""Population-scale seams: sample-axis sharding, affinity layout,
grouped sampling, and the hierarchical two-tier aggregation reduce.

Host-side pieces (vectorized shard construction, affinity re-layout,
grouped cohort draw, tier byte accounting, config validation) run on any
device count. The mesh cases — device-local gather determinism and
hierarchical-vs-flat engine equivalence — need 8 forced devices
(``REPRO_TEST_DEVICES=8``; they skip cleanly otherwise). Tolerances
follow tests/test_shard_engine.py: fp32-tight where the reduction order
changes, exact where only data placement moves.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.round_engine_bench import EQUIV_TOL
from repro.core import CompressionConfig, agg_tier_bytes, hierarchical_psum
from repro.data import (ClientShards, FederatedData, iid_partition,
                        make_image_dataset)
from repro.federated import FLConfig, run_training_scan
from repro.federated.sampling import (sample_clients_grouped,
                                      sample_clients_jax)
from repro.launch.mesh import CLIENT_AXIS, make_client_mesh, shard_map_norep

ATOL = EQUIV_TOL
needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices; set REPRO_TEST_DEVICES=8 (or XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


# ----------------------------------------------------------------------
# vectorized shard construction (from_federated without the O(N*S) loop)
# ----------------------------------------------------------------------
def _ragged_fldata(n_clients=7, seed=0):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 6, size=n_clients)
    total = int(sizes.sum())
    xs = rng.standard_normal((total, 4)).astype(np.float32)
    ys = rng.integers(0, 3, size=total).astype(np.int32)
    perm = rng.permutation(total)
    splits = np.cumsum(sizes)[:-1]
    return FederatedData(xs, ys, np.split(perm, splits))


def _loop_reference(parts, smax=None):
    """The original per-client construction: row c = parts[c][m % |D_c|]."""
    width = smax or max(len(p) for p in parts)
    idx = np.zeros((len(parts), width), dtype=np.int32)
    for c, p in enumerate(parts):
        p = p[:width]
        for m in range(width):
            idx[c, m] = p[m % len(p)]
    return idx


def test_from_federated_matches_loop_reference():
    fldata = _ragged_fldata()
    shards = ClientShards.from_federated(fldata)
    np.testing.assert_array_equal(np.asarray(shards.part_idx),
                                  _loop_reference(fldata.parts))
    np.testing.assert_array_equal(
        np.asarray(shards.part_sizes),
        np.array([len(p) for p in fldata.parts], dtype=np.int32))


def test_from_federated_shard_cap():
    fldata = _ragged_fldata()
    cap = 2
    shards = ClientShards.from_federated(fldata, max_shard_cap=cap)
    assert shards.part_idx.shape[1] == cap
    np.testing.assert_array_equal(np.asarray(shards.part_idx),
                                  _loop_reference(fldata.parts, smax=cap))
    np.testing.assert_array_equal(
        np.asarray(shards.part_sizes),
        np.minimum([len(p) for p in fldata.parts], cap).astype(np.int32))
    with pytest.raises(ValueError, match="max_shard_cap"):
        ClientShards.from_federated(fldata, max_shard_cap=0)


# ----------------------------------------------------------------------
# grouped cohort sampling
# ----------------------------------------------------------------------
def test_grouped_sampler_respects_group_ranges():
    key = jax.random.PRNGKey(7)
    n, k, g = 32, 8, 4
    cohort = np.asarray(sample_clients_grouped(key, n, k, g))
    assert cohort.shape == (k,)
    per = k // g
    for i in range(g):
        block = cohort[i * per:(i + 1) * per]
        assert ((block >= i * n // g) & (block < (i + 1) * n // g)).all()
        assert len(set(block.tolist())) == per          # distinct in group


def test_grouped_sampler_degenerates_to_flat():
    key = jax.random.PRNGKey(3)
    np.testing.assert_array_equal(
        np.asarray(sample_clients_grouped(key, 10, 4, 1)),
        np.asarray(sample_clients_jax(key, 10, 4)))


def test_grouped_sampler_divisibility_errors():
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="divide"):
        sample_clients_grouped(key, 10, 4, 4)           # N % G
    with pytest.raises(ValueError, match="divide"):
        sample_clients_grouped(key, 16, 6, 4)           # K % G


# ----------------------------------------------------------------------
# affinity re-layout
# ----------------------------------------------------------------------
def test_with_affinity_preserves_gather_values():
    fldata = _ragged_fldata(n_clients=8, seed=1)
    shards = ClientShards.from_federated(fldata)
    aff = shards.with_affinity(4)
    assert aff.num_groups == 4 and aff.group_block > 0
    key = jax.random.PRNGKey(5)
    clients = jnp.asarray([0, 3, 5, 6])
    b0 = shards.gather(clients, batch=3, key=key)
    b1 = aff.gather(clients, batch=3, key=key)
    for k in b0:
        np.testing.assert_array_equal(np.asarray(b0[k]), np.asarray(b1[k]))
    assert aff.with_affinity(4) is aff                  # idempotent
    with pytest.raises(ValueError, match="groups"):
        shards.with_affinity(3)                         # 8 % 3


# ----------------------------------------------------------------------
# hierarchical reduce + tier byte accounting
# ----------------------------------------------------------------------
@needs8
@pytest.mark.parametrize("group_size", [1, 2, 4, 8])
def test_hierarchical_psum_equals_flat(group_size):
    mesh = make_client_mesh(8)
    x = jnp.arange(8 * 3, dtype=jnp.float32).reshape(8, 3)
    from jax.sharding import PartitionSpec as P

    def flat(v):
        return jax.lax.psum(v, CLIENT_AXIS)

    def hier(v):
        return hierarchical_psum(v, CLIENT_AXIS, axis_size=8,
                                 group_size=group_size)

    kw = dict(in_specs=P(CLIENT_AXIS), out_specs=P())
    ref = shard_map_norep(flat, mesh, **kw)(x)
    got = shard_map_norep(hier, mesh, **kw)(x)
    # integer-valued fp32 data: the ring and the flat reduce agree exactly
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_agg_tier_bytes_topology():
    p = 100.0
    flat = agg_tier_bytes(p, 8, 0)
    assert flat["agg_tiers"] == 1.0 and flat["agg_groups"] == 1.0
    assert flat["agg_intra_bytes"] == 0.0
    assert flat["agg_cross_bytes"] == 7 * p
    assert flat["agg_cross_bytes_per_host"] == 14 * p
    hier = agg_tier_bytes(p, 8, 2)          # 4 groups of 2
    assert hier["agg_tiers"] == 2.0 and hier["agg_groups"] == 4.0
    assert hier["agg_intra_bytes"] == 4 * p
    assert hier["agg_cross_bytes"] == 12 * p
    # busiest ring member moves 2*(G-1) payloads < the flat root's 2*(D-1)
    assert hier["agg_cross_bytes_per_host"] == 6 * p
    assert agg_tier_bytes(p, 8, 8)["agg_tiers"] == 1.0   # gs == D: flat
    with pytest.raises(ValueError, match="divide"):
        agg_tier_bytes(p, 8, 3)


# ----------------------------------------------------------------------
# config validation + multi-process mesh seam
# ----------------------------------------------------------------------
def _base_cfg(**kw):
    return FLConfig(algo="fedavg", num_clients=8, clients_per_round=4,
                    top_n=2, mode="vmap", batch_per_client=2, **kw)


def test_flconfig_mesh_knob_validation():
    with pytest.raises(ValueError, match="mesh"):
        _base_cfg(agg_group_size=2)                     # off-mesh
    with pytest.raises(ValueError, match="mesh"):
        _base_cfg(shard_samples=True)                   # off-mesh
    mesh = make_client_mesh(1)
    with pytest.raises(ValueError, match="agg_group_size"):
        _base_cfg(mesh=mesh, agg_group_size=2)          # gs > d
    if len(jax.devices()) >= 2:
        with pytest.raises(ValueError, match="divisible"):
            FLConfig(algo="fedavg", num_clients=9, clients_per_round=4,
                     top_n=2, mode="vmap", batch_per_client=2,
                     mesh=make_client_mesh(2), shard_samples=True)


def test_make_client_mesh_process_count_mismatch():
    # single-process session: asking for a 2-process mesh must fail loudly
    with pytest.raises(ValueError, match="process"):
        make_client_mesh(processes=2)
    # processes=None and processes=1 build the same single-process mesh
    m0 = make_client_mesh(1)
    m1 = make_client_mesh(1, processes=1)
    assert m0.axis_names == m1.axis_names
    assert list(m0.devices.flat) == list(m1.devices.flat)


# ----------------------------------------------------------------------
# mesh cases: device-local gather + engine equivalence (8 devices)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def task16():
    train, _ = make_image_dataset(num_train=320, num_test=16, seed=1)
    parts = iid_partition(train.ys, 16, seed=0)
    data = FederatedData(train.xs, train.ys, parts)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    params = {"l1": {"w": jax.random.normal(ks[0], (3072, 16)) * 0.02,
                     "b": jnp.zeros((16,))},
              "head": {"w": jax.random.normal(ks[1], (16, 10)) * 0.1,
                       "b": jnp.zeros((10,))}}
    return params, data


def _loss(params, batch):
    x = batch["images"].reshape(batch["images"].shape[0], -1)
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    logits = h @ params["head"]["w"] + params["head"]["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, batch["labels"][:, None],
                                axis=-1).mean()


def _cfg16(mesh, algo="fedldf", **kw):
    return FLConfig(algo=algo, num_clients=16, clients_per_round=8,
                    top_n=2, mode="vmap", batch_per_client=4, mesh=mesh,
                    **kw)


def _assert_trees_close(a, b, atol=ATOL):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol)


@needs8
def test_affinity_gather_device_local_matches_replicated(task16):
    """The device-local gather (sample-sharded placement, shard_map index
    rebase) returns bit-identical batches to the replicated-placement
    global take, for a per-group cohort on the same key."""
    _, data = task16
    mesh = make_client_mesh(8)
    aff = ClientShards.from_federated(data).with_affinity(8)
    rep = aff.place(mesh)                       # replicated arrays
    shd = aff.place(mesh, shard_samples=True)   # 1/8 sample blocks
    assert shd.bytes_per_device() * 8 <= rep.bytes_per_device() + 8 * 8

    key = jax.random.PRNGKey(11)
    clients = sample_clients_grouped(key, 16, 8, 8)
    b_rep = jax.jit(lambda c, k: rep.gather(c, 4, k, mesh=mesh))(
        clients, key)
    b_shd = jax.jit(lambda c, k: shd.gather(c, 4, k, mesh=mesh))(
        clients, key)
    for name in b_rep:
        np.testing.assert_array_equal(np.asarray(b_rep[name]),
                                      np.asarray(b_shd[name]))


@needs8
@pytest.mark.parametrize("algo", ["fedldf", "fedavg"])
@pytest.mark.parametrize("group_size", [2, 4])
def test_hierarchical_engine_matches_flat(task16, algo, group_size):
    """Two-tier reduce (group psum + leader ring) reproduces the flat
    single-psum trajectory — params, losses, and comm totals — on a fixed
    seed (fp32 tolerance: the ring changes the fp32 summation order)."""
    params, data = task16
    mesh = make_client_mesh(8)
    p0, l0 = run_training_scan(params, _loss, data, _cfg16(mesh, algo),
                               rounds=4, seed=3)
    p1, l1 = run_training_scan(params, _loss, data,
                               _cfg16(mesh, algo, agg_group_size=group_size),
                               rounds=4, seed=3)
    _assert_trees_close(p0, p1)
    np.testing.assert_allclose(l0.losses, l1.losses, atol=ATOL)
    assert l0.meter.uplink_bytes == pytest.approx(l1.meter.uplink_bytes)
    assert l0.meter.downlink_bytes == pytest.approx(l1.meter.downlink_bytes)


@needs8
def test_hierarchical_engine_with_compression(task16):
    """EF residual scatter + packed quantized uplink accounting both ride
    the tier-1 group reduce; the trajectory must still match flat."""
    params, data = task16
    mesh = make_client_mesh(8)
    comp = CompressionConfig(bits=4, error_feedback=True)
    p0, l0 = run_training_scan(params, _loss, data,
                               _cfg16(mesh, compression=comp),
                               rounds=3, seed=0)
    p1, l1 = run_training_scan(params, _loss, data,
                               _cfg16(mesh, compression=comp,
                                      agg_group_size=4),
                               rounds=3, seed=0)
    _assert_trees_close(p0, p1)
    assert l0.meter.uplink_bytes == pytest.approx(l1.meter.uplink_bytes)


@needs8
def test_sample_sharded_trajectory_matches_replicated(task16):
    """End-to-end shard_samples=True run vs replicated placement of the
    same affinity layout: identical participants (grouped draw both
    sides), so the trajectories agree to fp32 tolerance."""
    params, data = task16
    mesh = make_client_mesh(8)
    aff = ClientShards.from_federated(data).with_affinity(8)
    p0, l0 = run_training_scan(params, _loss, aff.place(mesh),
                               _cfg16(mesh), rounds=4, seed=2)
    p1, l1 = run_training_scan(params, _loss, aff,
                               _cfg16(mesh, shard_samples=True),
                               rounds=4, seed=2)
    _assert_trees_close(p0, p1)
    np.testing.assert_allclose(l0.losses, l1.losses, atol=ATOL)
    assert l0.meter.uplink_bytes == pytest.approx(l1.meter.uplink_bytes)
