"""Beyond-paper quantized-delta upload + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compress import compress_upload, quantize_unit_symmetric
from repro.core.units import UnitMap
from repro.federated import FLConfig, build_round_fn
from repro.models import cnn

CFG = cnn.VGGConfig().reduced()


def _loss(p, b):
    return cnn.classify_loss(p, CFG, b)


def _g_rel_l2(a, b):
    num = sum(float(jnp.sum((x - y) ** 2))
              for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    den = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(a))
    return (num / den) ** 0.5


@pytest.fixture(scope="module")
def setup():
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    umap = UnitMap.build(params)
    local = jax.tree.map(
        lambda l: l + 0.01 * jax.random.normal(jax.random.PRNGKey(1),
                                               l.shape), params)
    return params, umap, local


@pytest.mark.parametrize("bits,tol", [(8, 0.01), (4, 0.12), (2, 0.7)])
def test_quantize_roundtrip_error_bounded(setup, bits, tol):
    g, umap, local = setup
    theta_hat, _ = compress_upload(local, g, umap, bits)
    delta_mag = max(float(jnp.abs(a - b).max())
                    for a, b in zip(jax.tree.leaves(local),
                                    jax.tree.leaves(g)))
    recon_err = max(float(jnp.abs(a - b).max())
                    for a, b in zip(jax.tree.leaves(theta_hat),
                                    jax.tree.leaves(local)))
    assert recon_err <= tol * delta_mag


def test_levels_within_range(setup):
    g, umap, local = setup
    delta = jax.tree.map(jnp.subtract, local, g)
    levels, scales = quantize_unit_symmetric(delta, umap, 8)
    for leaf in jax.tree.leaves(levels):
        assert float(jnp.abs(leaf).max()) <= 127
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.round(np.asarray(leaf)))
    assert scales.shape == (umap.num_units,)
    assert (np.asarray(scales) > 0).all()


def test_error_feedback_reduces_bias(setup):
    """With EF, the running (delta − sent) residual is carried and the sum
    of sent messages tracks the sum of true deltas (quantization noise is
    compensated rather than accumulated)."""
    g, umap, local = setup
    delta = jax.tree.map(jnp.subtract, local, g)
    res = None
    sent_sum = jax.tree.map(jnp.zeros_like, g)
    for _ in range(8):
        theta_hat, res = compress_upload(local, g, umap, 2, res)
        sent = jax.tree.map(jnp.subtract, theta_hat, g)
        sent_sum = jax.tree.map(jnp.add, sent_sum, sent)
    true_sum = jax.tree.map(lambda d: 8.0 * d, delta)
    err_ef = _g_rel_l2(true_sum, sent_sum)

    # without EF the same 8 uploads repeat the same biased message
    theta_nef, _ = compress_upload(local, g, umap, 2)
    sent_nef = jax.tree.map(lambda t, gg: 8.0 * (t - gg), theta_nef, g)
    err_nef = _g_rel_l2(true_sum, sent_nef)
    assert err_ef < err_nef * 0.8


def test_quantized_round_close_to_exact_and_cheaper():
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    umap = UnitMap.build(params)
    k = 4
    key = jax.random.PRNGKey(3)
    batch = {"images": jax.random.normal(key, (k, 8, 32, 32, 3)),
             "labels": jax.random.randint(key, (k, 8), 0, 10)}
    sizes = jnp.ones((k,))
    base = FLConfig(algo="fedldf", clients_per_round=k, top_n=2, mode="vmap")
    p0, m0 = jax.jit(build_round_fn(_loss, umap, base))(params, batch, sizes,
                                                        key)
    q = FLConfig(algo="fedldf", clients_per_round=k, top_n=2, mode="vmap",
                 quantize_bits=8)
    p1, m1 = jax.jit(build_round_fn(_loss, umap, q))(params, batch, sizes,
                                                     key)
    assert _g_rel_l2(p0, p1) < 5e-3
    # selection saving (1/2) × int8 (1/4) ≈ 0.875 total
    assert float(m1["comm"]["savings_frac"]) == pytest.approx(0.875,
                                                              abs=0.01)
    # selection itself must be identical (divergence on true local models)
    np.testing.assert_array_equal(np.asarray(m0["selection"]),
                                  np.asarray(m1["selection"]))
