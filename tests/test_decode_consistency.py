"""Serving-path correctness: prefill + incremental decode must reproduce the
full-forward logits for every model family (incl. sliding window, SSM state,
MoE routing, M-RoPE, enc-dec cross attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import decode as dec
from repro.models import transformer as tf
from repro.models.config import ModelConfig


def mk(family, **kw):
    base = dict(name="t-" + family, family=family, num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=97)
    base.update(kw)
    return ModelConfig(**base)


CASES = [
    mk("dense"),
    mk("dense", sliding_window=8),
    mk("dense", qk_norm=True, qkv_bias=True),
    mk("moe", num_experts=4, moe_top_k=2, moe_d_ff=32, num_shared_experts=1,
       d_ff=0, capacity_factor=8.0),
    mk("ssm", ssm_state=8, ssm_head_dim=16, ssm_chunk=8),
    mk("hybrid", ssm_state=8, ssm_head_dim=16, ssm_chunk=8),
    mk("vlm", mrope=True, mrope_sections=(4, 2, 2)),
    mk("audio", encoder_layers=2, frontend_dim=24),
]


@pytest.mark.parametrize("cfg", CASES, ids=lambda c: f"{c.name}-w{c.sliding_window}")
def test_decode_matches_forward(cfg):
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)
    s, steps = 13, 4           # deliberately not a chunk multiple
    toks = jax.random.randint(key, (2, s + steps), 0, cfg.vocab_size)
    enc = (jax.random.normal(key, (2, 13, 24))
           if cfg.is_encdec else None)
    logits_full, _ = tf.forward(params, cfg, toks, enc_inputs=enc)

    lg, cache = dec.prefill(params, cfg, toks[:, :s], enc_inputs=enc,
                            max_len=s + steps)
    np.testing.assert_allclose(lg, logits_full[:, s - 1], rtol=1e-4,
                               atol=1e-4)
    for t in range(steps):
        lg, cache = dec.decode_step(params, cfg, toks[:, s + t:s + t + 1],
                                    cache)
        np.testing.assert_allclose(lg, logits_full[:, s + t], rtol=1e-4,
                                   atol=1e-4)


def test_ring_buffer_eviction_matches_window():
    """With a full ring buffer, decode == forward restricted to the window."""
    cfg = mk("dense", sliding_window=6)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 20), 0, 97)
    logits_full, _ = tf.forward(params, cfg, toks)
    lg, cache = dec.prefill(params, cfg, toks[:, :10])
    for t in range(10, 20):
        lg, cache = dec.decode_step(params, cfg, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(lg, logits_full[:, t], rtol=1e-4,
                                   atol=1e-4)


def test_flash_path_matches_block_path():
    """Chunked-flash attention (long KV) == single-block attention."""
    from repro.models import attention as attn
    key = jax.random.PRNGKey(0)
    b, sq, h, kvh, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (b, sq, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sq, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sq, kvh, hd))
    pos = jnp.arange(sq)
    block = attn.attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                        flash_threshold=10_000)
    flash = attn.attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                        flash_threshold=1, chunk=16)
    np.testing.assert_allclose(block, flash, rtol=2e-4, atol=2e-5)


def test_flash_path_sliding_window():
    from repro.models import attention as attn
    key = jax.random.PRNGKey(0)
    b, sq, h, kvh, hd = 1, 48, 2, 2, 8
    q = jax.random.normal(key, (b, sq, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sq, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sq, kvh, hd))
    pos = jnp.arange(sq)
    block = attn.attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                        window=7, flash_threshold=10_000)
    flash = attn.attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                        window=7, flash_threshold=1, chunk=16)
    np.testing.assert_allclose(block, flash, rtol=2e-4, atol=2e-5)
