"""Packed wire format: roundtrip exactness, byte accounting, fused kernel
equivalence, divergence-driven bit allocation, and the FLConfig shims."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, wire
from repro.core.compress import compress_upload, quantize_unit_symmetric
from repro.core.units import UnitMap
from repro.core.wire import (UNIT_HEADER_BYTES, CompressionConfig,
                             PackedPayload, allocate_bits)
from repro.federated import FLConfig, build_round_fn
from repro.federated.strategies import make_strategy
from repro.kernels import ref
from repro.models import cnn

CFG = cnn.VGGConfig().reduced()


def _loss(p, b):
    return cnn.classify_loss(p, CFG, b)


def _tree_max_abs_diff(a, b):
    return max(float(jnp.abs(x.astype(jnp.float32)
                             - y.astype(jnp.float32)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.fixture(scope="module")
def setup():
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    umap = UnitMap.build(params)
    local = jax.tree.map(
        lambda l: l + 0.01 * jax.random.normal(jax.random.PRNGKey(1),
                                               l.shape), params)
    return params, umap, local


# ----------------------------------------------------------------------
# roundtrip: pack → unpack/dequantize against the pre-wire fp32 chain
# ----------------------------------------------------------------------
def test_pack_roundtrip_int8_matches_legacy_exactly(setup):
    g, umap, local = setup
    delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), local, g)
    bits = jnp.full((umap.num_units,), 8.0, jnp.float32)
    payload = wire.pack(delta, umap, bits, storage_bits=8)
    recon = wire.dequantize(payload, umap, delta)

    # int8 storage is lossless for 8-bit levels: the wire path must agree
    # with the legacy fp32 chain bit-for-bit (compare at the Θ̂ level so
    # both sides use the same op order — Ĝ + recon)
    theta_hat, _ = compress_upload(local, g, umap, 8)
    theta_wire = jax.tree.map(
        lambda gg, r: (gg.astype(jnp.float32) + r).astype(gg.dtype),
        g, recon)
    assert _tree_max_abs_diff(theta_wire, theta_hat) == 0.0

    levels, scales = quantize_unit_symmetric(delta, umap, 8)
    np.testing.assert_array_equal(np.asarray(payload.scales),
                                  np.asarray(scales))
    for a, b in zip(jax.tree.leaves(payload.levels),
                    jax.tree.leaves(levels)):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(b).astype(np.int8))


def test_pack_roundtrip_int4_nibbles(setup):
    g, umap, local = setup
    delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), local, g)
    bits = jnp.full((umap.num_units,), 4.0, jnp.float32)
    levels, _ = wire.quantize_units(delta, umap, bits)
    payload = wire.pack(delta, umap, bits, storage_bits=4)
    # nibble packing halves the last axis (rounded up)
    for lv, pk in zip(jax.tree.leaves(levels), jax.tree.leaves(payload.levels)):
        assert pk.dtype == jnp.int8
        assert pk.shape[-1] == (lv.shape[-1] + 1) // 2
    # and unpacks losslessly — 4-bit levels live in [-7, 7]
    unpacked = wire.unpack_levels(payload, delta)
    for lv, up in zip(jax.tree.leaves(levels), jax.tree.leaves(unpacked)):
        np.testing.assert_array_equal(np.asarray(lv).astype(np.int8),
                                      np.asarray(up))
    recon = wire.dequantize(payload, umap, delta)
    tol = 0.12 * _tree_max_abs_diff(delta, jax.tree.map(jnp.zeros_like,
                                                        delta))
    assert _tree_max_abs_diff(recon, delta) <= tol


def test_pack4_odd_tail():
    x = jnp.arange(-7, 8, dtype=jnp.int8).reshape(3, 5)  # odd last dim
    out = wire._unpack4(wire._pack4(x), 5)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


# ----------------------------------------------------------------------
# byte accounting: nbytes / unit_wire_bytes / round_comm form one ledger
# ----------------------------------------------------------------------
def test_nbytes_matches_unit_wire_bytes_int8(setup):
    g, umap, local = setup
    delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), local, g)
    bits = jnp.full((umap.num_units,), 8.0, jnp.float32)
    payload = wire.pack(delta, umap, bits, storage_bits=8)
    # at 8 bits the logical wire cost (ceil(p·8/8) + header per unit) is
    # exactly the physical packed size: levels + fp32 scale + width byte
    logical = float(jnp.sum(payload.unit_wire_bytes(umap)))
    assert logical == float(payload.nbytes)
    assert payload.nbytes == (umap.total_params
                              + (4 + 1) * umap.num_units)
    assert UNIT_HEADER_BYTES == 5


def test_nbytes_int4_padding_slack_bounded(setup):
    g, umap, local = setup
    delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), local, g)
    bits = jnp.full((umap.num_units,), 4.0, jnp.float32)
    payload = wire.pack(delta, umap, bits, storage_bits=4)
    logical = float(jnp.sum(payload.unit_wire_bytes(umap)))
    # physical nibble packing pads odd last-dims per *leaf row*; the
    # logical per-unit ceil can only be under it, and the slack is at most
    # one byte per packed row
    rows = sum(int(np.prod(l.shape[:-1]))
               for l in jax.tree.leaves(payload.levels))
    assert payload.nbytes >= logical - rows
    assert payload.nbytes <= logical + rows


def test_comm_profile_prices_packed_bytes(setup):
    g, umap, local = setup
    delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), local, g)
    bits = jnp.full((umap.num_units,), 8.0, jnp.float32)
    payload = wire.pack(delta, umap, bits, storage_bits=8)
    unit_bytes = payload.unit_wire_bytes(umap)

    k, u = 4, umap.num_units
    sel = (jax.random.uniform(jax.random.PRNGKey(2), (k, u)) < 0.5
           ).astype(jnp.float32)
    flcfg = FLConfig(algo="fedldf", clients_per_round=k, mode="vmap",
                     compression=CompressionConfig(bits=8))
    strat = make_strategy(flcfg)
    prof = strat.comm_profile(sel, umap, unit_bytes_override=unit_bytes)

    # the invariant: payload bytes == Σ selection · per-unit wire bytes,
    # and payload + feedback == total
    expect = float(jnp.sum(sel * unit_bytes[None, :]))
    assert float(prof["uplink_payload"]) == pytest.approx(expect, rel=1e-6)
    assert float(prof["uplink_total"]) == pytest.approx(
        float(prof["uplink_payload"]) + float(prof["uplink_feedback"]),
        rel=1e-6)
    # and it agrees with core.comm directly
    ref_prof = comm.round_comm(sel, umap, unit_bytes_override=unit_bytes)
    assert float(prof["uplink_total"]) == pytest.approx(
        float(ref_prof["uplink_total"]), rel=1e-6)


def test_comm_profile_static_fallback_prices_headers(setup):
    _, umap, _ = setup
    k, u = 4, umap.num_units
    sel = jnp.ones((k, u), jnp.float32)
    flcfg = FLConfig(algo="fedldf", clients_per_round=k, mode="vmap",
                     compression=CompressionConfig(bits=8))
    strat = make_strategy(flcfg)
    prof = strat.comm_profile(sel, umap)   # no per-round wire vector
    p = np.asarray(umap.unit_params, np.float64)
    expect = k * float((np.ceil(p * 8 / 8) + UNIT_HEADER_BYTES).sum())
    assert float(prof["uplink_payload"]) == pytest.approx(expect, rel=1e-6)


# ----------------------------------------------------------------------
# fused uplink kernel (interpret-mode Pallas) vs the jnp oracle
# ----------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 1, 1), (3, 7, 129), (4, 16, 2048),
                                   (5, 33, 2049)])
def test_fused_uplink_pallas_matches_ref(monkeypatch, shape):
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    from repro.kernels import ops as kops
    k_, r, c = shape
    key = jax.random.PRNGKey(r * c)
    ks = jax.random.split(key, 3)
    levels = jax.random.randint(ks[0], shape, -127, 128).astype(jnp.int8)
    scales = jax.random.uniform(ks[1], (k_, r), minval=1e-4)
    w = jax.random.uniform(ks[2], (k_, r))
    out = kops.fused_uplink(levels, scales, w)
    exp = ref.fused_uplink(levels, scales, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(2, 5, 64), (4, 16, 2048), (3, 9, 515)])
def test_fused_uplink_ef_pallas_matches_ref(monkeypatch, shape):
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    from repro.kernels import ops as kops
    k_, r, c = shape
    ks = jax.random.split(jax.random.PRNGKey(c), 5)
    levels = jax.random.randint(ks[0], shape, -127, 128).astype(jnp.int8)
    scales = jax.random.uniform(ks[1], (k_, r), minval=1e-4)
    w = jax.random.uniform(ks[2], (k_, r))
    gate = (jax.random.uniform(ks[3], (k_, r)) < 0.5).astype(jnp.float32)
    v = jax.random.normal(ks[4], shape)
    e_old = jax.random.normal(ks[0], shape)
    num, res = kops.fused_uplink_ef(levels, scales, w, gate, v, e_old)
    enum, eres = ref.fused_uplink_ef(levels, scales, w, gate, v, e_old)
    np.testing.assert_allclose(np.asarray(num), np.asarray(enum),
                               rtol=3e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res), np.asarray(eres),
                               rtol=3e-5, atol=1e-5)
    # EF residual gating: unselected rows keep e_old exactly
    off = np.asarray(gate) == 0.0
    np.testing.assert_array_equal(np.asarray(res)[off],
                                  np.asarray(e_old)[off])


# ----------------------------------------------------------------------
# end-to-end: fused packed path vs the legacy unfused chain, fixed seed
# ----------------------------------------------------------------------
def _one_round(flcfg, params, umap, rng, state=None):
    k = flcfg.clients_per_round
    batch = {"images": jax.random.normal(rng, (k, 8, 32, 32, 3)),
             "labels": jax.random.randint(rng, (k, 8), 0, 10)}
    sizes = jnp.ones((k,))
    fn = jax.jit(build_round_fn(_loss, umap, flcfg))
    return fn(params, batch, sizes, rng, state)


@pytest.mark.parametrize("ef", [False, True], ids=["noef", "ef"])
def test_fused_trajectory_matches_legacy(ef):
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    umap = UnitMap.build(params)
    mk = lambda fused: FLConfig(
        algo="fedldf", num_clients=4, clients_per_round=4, top_n=2,
        mode="vmap",
        compression=CompressionConfig(bits=8, error_feedback=ef,
                                      fused=fused))
    cf, cl = mk(True), mk(False)
    # EF residual rows ride the strategy-state seam, as in the drivers
    sf = make_strategy(cf).init_state(params, 4)
    sl = make_strategy(cl).init_state(params, 4)
    pf, pl = params, params
    for r in range(3):
        rng = jax.random.PRNGKey(100 + r)
        pf, mf = _one_round(cf, pf, umap, rng, sf)
        pl, ml = _one_round(cl, pl, umap, rng, sl)
        sf, sl = mf.get("state", sf), ml.get("state", sl)
        # same math, different fp32 summation order (the fused path adds
        # denom·Ĝ once instead of accumulating Ĝ per client), so the
        # trajectories agree to fp32 tolerance, not bit-for-bit
        num = sum(float(jnp.sum((x - y) ** 2))
                  for x, y in zip(jax.tree.leaves(pf), jax.tree.leaves(pl)))
        den = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(pf))
        assert (num / den) ** 0.5 < 1e-4
        np.testing.assert_array_equal(np.asarray(mf["selection"]),
                                      np.asarray(ml["selection"]))
    # packed pricing adds only the per-unit header vs legacy b/8 pricing
    assert float(mf["comm"]["savings_frac"]) == pytest.approx(
        float(ml["comm"]["savings_frac"]), abs=0.01)


# ----------------------------------------------------------------------
# divergence-driven bit allocation
# ----------------------------------------------------------------------
def test_allocate_bits_budget_and_bounds(setup):
    _, umap, _ = setup
    u = umap.num_units
    divs = jax.random.uniform(jax.random.PRNGKey(5), (6, u), minval=0.1)
    b = allocate_bits(divs, umap, avg_bits=4.0, min_bits=2, max_bits=8)
    bn = np.asarray(b)
    assert bn.shape == (u,)
    np.testing.assert_array_equal(bn, np.round(bn))  # integer widths
    assert (bn >= 2).all() and (bn <= 8).all()
    p = np.asarray(umap.unit_params, np.float64)
    assert (p * bn).sum() / p.sum() <= 4.0 + 1e-6    # respects the budget


def test_allocate_bits_uniform_energy_hits_budget(setup):
    _, umap, _ = setup
    # per-parameter divergence energy identical across units → every unit
    # sits at the budget
    p = jnp.asarray(umap.unit_params, jnp.float32)
    divs = jnp.sqrt(p)[None, :]
    b = np.asarray(allocate_bits(divs, umap, avg_bits=4.0))
    np.testing.assert_array_equal(b, np.full_like(b, 4.0))


def test_allocate_bits_monotone_in_divergence(setup):
    _, umap, _ = setup
    u = umap.num_units
    p = jnp.asarray(umap.unit_params, jnp.float32)
    # unit 0 diverges 100× more per parameter than the rest
    energy = jnp.ones((u,)).at[0].set(100.0)
    divs = jnp.sqrt(energy * p)[None, :]
    b = np.asarray(allocate_bits(divs, umap, avg_bits=4.0))
    assert b[0] > b[1:].max()


def test_auto_bits_trains_and_saves_more_than_8bit():
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    umap = UnitMap.build(params)
    rng = jax.random.PRNGKey(11)
    auto = FLConfig(algo="fedldf", clients_per_round=4, top_n=2,
                    mode="vmap",
                    compression=CompressionConfig(bits="auto", avg_bits=4.0))
    fixed = FLConfig(algo="fedldf", clients_per_round=4, top_n=2,
                     mode="vmap", compression=CompressionConfig(bits=8))
    pa, ma = _one_round(auto, params, umap, rng)
    _, mf = _one_round(fixed, params, umap, rng)
    assert np.isfinite(float(ma["loss"]))
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(pa))
    # ≤4-bit average beats uniform 8-bit on the wire
    assert float(ma["comm"]["uplink_total"]) < float(mf["comm"]["uplink_total"])


def test_auto_requires_divergence_stats(setup):
    _, umap, _ = setup
    with pytest.raises(ValueError, match="divergence"):
        CompressionConfig(bits="auto").bits_vector(umap, None)


# ----------------------------------------------------------------------
# CompressionConfig validation + FLConfig deprecation shims
# ----------------------------------------------------------------------
def test_compression_config_validation():
    with pytest.raises(ValueError, match=r"\[2, 8\]"):
        CompressionConfig(bits=1)
    with pytest.raises(ValueError, match=r"\[2, 8\]"):
        CompressionConfig(bits=9)
    with pytest.raises(ValueError, match="auto"):
        CompressionConfig(bits="adaptive")
    with pytest.raises(ValueError, match="waterfill"):
        CompressionConfig(allocation="greedy")
    with pytest.raises(ValueError, match="avg_bits"):
        CompressionConfig(bits="auto", avg_bits=10.0)
    with pytest.raises(ValueError, match="fused"):
        CompressionConfig(bits="auto", fused=False)
    assert CompressionConfig(bits=4).storage_bits == 4
    assert CompressionConfig(bits=5).storage_bits == 8
    assert CompressionConfig(bits="auto", max_bits=4).storage_bits == 4


def test_flcfg_quantize_shim_warns_and_normalizes():
    with pytest.warns(DeprecationWarning, match="CompressionConfig"):
        old = FLConfig(algo="fedldf", clients_per_round=4, mode="vmap",
                       quantize_bits=8, error_feedback=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # new spelling must not warn
        new = FLConfig(algo="fedldf", clients_per_round=4, mode="vmap",
                       compression=CompressionConfig(bits=8,
                                                     error_feedback=True))
    assert old == new and hash(old) == hash(new)
    assert old.compression == CompressionConfig(bits=8, error_feedback=True)
    assert new.quantize_bits == 8 and new.error_feedback  # mirrored back


def test_flcfg_quantize_shim_conflict_raises():
    with pytest.raises(ValueError):
        FLConfig(algo="fedldf", clients_per_round=4, mode="vmap",
                 quantize_bits=4,
                 compression=CompressionConfig(bits=8))


def test_flcfg_algo_options_shim():
    from repro.federated import FedLPOptions
    with pytest.warns(DeprecationWarning, match="algo_options"):
        old = FLConfig(algo="fedlp", clients_per_round=4, mode="vmap",
                       fedlp_p=0.25)
    new = FLConfig(algo="fedlp", clients_per_round=4, mode="vmap",
                   algo_options=FedLPOptions(p=0.25))
    assert old == new
    assert new.fedlp_p == 0.25          # mirrored back for old readers
    with pytest.raises(ValueError):
        FLConfig(algo="fedlp", clients_per_round=4, mode="vmap",
                 fedlp_p=0.75, algo_options=FedLPOptions(p=0.25))


def test_flcfg_equivalent_spellings_share_strategy_behaviour():
    import dataclasses as dc
    cfg = FLConfig(algo="fedldf", clients_per_round=4, mode="vmap",
                   compression=CompressionConfig(bits=8))
    again = dc.replace(cfg)             # normalized configs must round-trip
    assert cfg == again
    strat = make_strategy(cfg)
    assert strat.packed_upload and not strat.transforms_upload
    legacy = make_strategy(dc.replace(
        cfg, compression=CompressionConfig(bits=8, fused=False)))
    assert legacy.transforms_upload and not legacy.packed_upload


def test_scan_compression_error_names_config_and_drivers():
    """The scan-engine refusal must tell the user what to reach for: the
    config class spelling and every driver that does support the packed
    uplink."""
    with pytest.raises(NotImplementedError) as ei:
        FLConfig(algo="fedldf", mode="scan",
                 compression=CompressionConfig(bits=8))
    msg = str(ei.value)
    for needle in ("CompressionConfig", "mode='vmap'", "mesh",
                   "run_training", "run_training_scan"):
        assert needle in msg, needle
    # the direct build_round_scan entry point refuses with the same message
    from repro.federated import build_round_scan
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    umap = UnitMap.build(params)
    fl = FLConfig(algo="fedldf", clients_per_round=4,
                  compression=CompressionConfig(bits=8))
    with pytest.raises(NotImplementedError) as ei2:
        build_round_scan(_loss, umap, fl)
    assert str(ei2.value) == msg
