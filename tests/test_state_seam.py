"""Cross-round strategy state seam.

Covers the seam itself (a toy rotating-selection strategy whose trajectory
depends on its state must agree across the host-vmap, jitted-scan, and
mesh-sharded drivers; stateless strategies must pay zero carry overhead),
the EF residual store re-expressed as declared client state, server-state
checkpoint round-trips (save → load → continue bit-identically), and the
FedLAMA proof strategy (round-0 full sync, interval adaptation, driver
agreement, uplink below FedAvg)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_server_state, save_server_state
from repro.data import FederatedData, iid_partition, make_image_dataset
from repro.federated import (FLConfig, FLStrategy, build_round_fn,
                             make_strategy, register_strategy, run_training,
                             run_training_scan, unregister_strategy)
from repro.launch.mesh import make_client_mesh

N_CLIENTS, K = 8, 4
STATELESS = ("fedldf", "fedavg", "random", "hdfl", "fedadp", "fedlp")

needs_devices = [
    pytest.param(d, marks=pytest.mark.skipif(
        len(jax.devices()) < d,
        reason=f"needs {d} devices; set REPRO_TEST_DEVICES=8"))
    for d in (1, 2)
]


def _mlp_params(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    return {
        "l1": {"w": jax.random.normal(ks[0], (3072, 16)) * 0.02,
               "b": jnp.zeros((16,))},
        "head": {"w": jax.random.normal(ks[1], (16, 10)) * 0.1,
                 "b": jnp.zeros((10,))},
    }


def _loss(params, batch):
    x = batch["images"].reshape(batch["images"].shape[0], -1)
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    logits = h @ params["head"]["w"] + params["head"]["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, batch["labels"][:, None],
                                axis=-1).mean()


@pytest.fixture(scope="module")
def task():
    train, _ = make_image_dataset(num_train=320, num_test=16, seed=1)
    parts = iid_partition(train.ys, N_CLIENTS, seed=0)
    data = FederatedData(train.xs, train.ys, parts)
    return _mlp_params(), data


def _cfg(algo="fedldf", mode="vmap", **kw):
    return FLConfig(algo=algo, num_clients=N_CLIENTS, clients_per_round=K,
                    top_n=2, mode=mode, batch_per_client=8, **kw)


def _assert_trees_equal(a, b, atol=0.0):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


# ----------------------------------------------------------------------
# The seam itself: a toy strategy whose selection depends on its state
# ----------------------------------------------------------------------
class RotatingClient(FLStrategy):
    """Round t: only participant slot (t mod K) uploads — the selection is
    a pure function of the cross-round counter, so any driver that drops,
    duplicates, or reorders a state update changes the whole trajectory."""

    def init_state(self, params, num_clients, mesh=None):
        return {"global": {"rounds": jnp.float32(0.0),
                           "sel_mass": jnp.float32(0.0)}}

    def select(self, divs, key, k, u, n):
        raise NotImplementedError("state-driven; engines use "
                                  "select_with_state")

    def select_with_state(self, state, divs, key, k, u, n):
        t = state["global"]["rounds"].astype(jnp.int32)
        row = (jnp.arange(k) == t % k).astype(jnp.float32)
        return jnp.broadcast_to(row[:, None], (k, u))

    def update_state(self, state, selection, divs, umap, key=None):
        g = state["global"]
        return {**state, "global": {
            "rounds": g["rounds"] + 1.0,
            "sel_mass": g["sel_mass"] + selection.sum()}}


@pytest.fixture()
def rotating():
    register_strategy("rotating")(RotatingClient)
    yield "rotating"
    unregister_strategy("rotating")


def test_state_trajectory_same_across_drivers(task, rotating):
    """vmap host driver, scan engine, and scan-client mode all observe the
    same state trajectory (and hence the same params)."""
    params, data = task
    rounds = 5
    ph, lh = run_training(params, _loss, data, _cfg(rotating), rounds=rounds,
                          seed=0, sampler="jax")
    ps, ls = run_training_scan(params, _loss, data, _cfg(rotating),
                               rounds=rounds, seed=0)
    pm, lm = run_training(params, _loss, data, _cfg(rotating, mode="scan"),
                          rounds=rounds, seed=0, sampler="jax")
    for log in (lh, ls, lm):
        g = jax.tree.map(float, log.final_state)["global"]
        assert g["rounds"] == rounds
    _assert_trees_equal(lh.final_state, ls.final_state)
    _assert_trees_equal(lh.final_state, lm.final_state)
    _assert_trees_equal(ph, ps, atol=2e-5)
    _assert_trees_equal(ph, pm, atol=2e-5)


@pytest.mark.parametrize("mesh_size", needs_devices)
def test_state_trajectory_under_mesh(task, rotating, mesh_size):
    """The shard_map driver threads the same state trajectory: global
    state enters replicated, leaves replicated, and the resulting
    trajectory matches the unsharded engine."""
    params, data = task
    p0, l0 = run_training_scan(params, _loss, data, _cfg(rotating),
                               rounds=4, seed=3)
    p1, l1 = run_training_scan(params, _loss, data,
                               _cfg(rotating, mesh=make_client_mesh(mesh_size)),
                               rounds=4, seed=3)
    _assert_trees_equal(l0.final_state, l1.final_state)
    _assert_trees_equal(p0, p1, atol=2e-5)


# ----------------------------------------------------------------------
# Stateless strategies: zero carry overhead
# ----------------------------------------------------------------------
@pytest.mark.parametrize("algo", STATELESS)
def test_stateless_strategies_have_no_state(algo):
    params = _mlp_params()
    fl = FLConfig(algo=algo, num_clients=N_CLIENTS, clients_per_round=K,
                  top_n=2)
    assert make_strategy(fl).init_state(params, N_CLIENTS) is None


def test_stateless_round_metrics_carry_no_state(task):
    """The compiled round of a stateless strategy must not grow any state
    output (no new scan-carry leaves vs the pre-seam engine)."""
    from repro.core.units import UnitMap
    params, _ = task
    umap = UnitMap.build(params)
    k = K
    key = jax.random.PRNGKey(0)
    batch = {"images": jax.random.normal(key, (k, 8, 32, 32, 3)),
             "labels": jax.random.randint(key, (k, 8), 0, 10)}
    sizes = jnp.full((k,), 10.0)
    fl = _cfg("fedavg")
    _, metrics = jax.jit(build_round_fn(_loss, umap, fl))(params, batch,
                                                          sizes, key)
    assert "state" not in metrics and "residuals" not in metrics
    _, ls = run_training_scan(params, _loss,
                              FederatedData(
                                  *_tiny_data()), fl, rounds=1, seed=0)
    assert ls.final_state is None


def _tiny_data():
    train, _ = make_image_dataset(num_train=160, num_test=8, seed=1)
    return train.xs, train.ys, iid_partition(train.ys, N_CLIENTS, seed=0)


# ----------------------------------------------------------------------
# EF residual store as declared client state
# ----------------------------------------------------------------------
def test_ef_store_is_client_state(task):
    params, data = task
    fl = _cfg(quantize_bits=4, error_feedback=True)
    state = make_strategy(fl).init_state(params, N_CLIENTS)
    store = state["client"]["residual"]
    for leaf, row in zip(jax.tree.leaves(params), jax.tree.leaves(store)):
        assert row.shape == (N_CLIENTS,) + leaf.shape
        assert row.dtype == leaf.dtype
        assert float(jnp.abs(row).max()) == 0.0
    # ... and the driver threads it: after training, some rows are nonzero
    _, log = run_training_scan(params, _loss, data, fl, rounds=3, seed=0)
    final = log.final_state["client"]["residual"]
    assert max(float(jnp.abs(l).max()) for l in jax.tree.leaves(final)) > 0


# ----------------------------------------------------------------------
# Checkpoint round-trip + resume
# ----------------------------------------------------------------------
@pytest.mark.parametrize("algo,kw", [
    ("fedlama", {}),                                      # global state
    ("fedldf", dict(quantize_bits=4, error_feedback=True)),  # client state
    ("fedavg", {}),                                       # stateless
])
def test_save_load_continue_matches_uninterrupted(task, tmp_path, algo, kw):
    """3 rounds + save → load + 3 more rounds == 6 uninterrupted rounds,
    bit-identically (same driver, same device, same key schedule)."""
    params, data = task
    fl = _cfg(algo, **kw)
    p_full, l_full = run_training_scan(params, _loss, data, fl, rounds=6,
                                       seed=0)
    p_half, l_half = run_training_scan(params, _loss, data, fl, rounds=3,
                                       seed=0)
    path = str(tmp_path / "server.npz")
    save_server_state(path, p_half, l_half.final_state)
    p_loaded, state_loaded = load_server_state(path)
    _assert_trees_equal(p_loaded, p_half)
    p_res, l_res = run_training_scan(p_loaded, _loss, data, fl, rounds=3,
                                     seed=0, start_round=3,
                                     server_state=state_loaded)
    _assert_trees_equal(p_full, p_res)
    if l_full.final_state is None:
        assert l_res.final_state is None and state_loaded is None
    else:
        _assert_trees_equal(l_full.final_state, l_res.final_state)


def test_host_driver_resume(task, tmp_path):
    """The host-loop driver (jax sampler) supports the same resume seam."""
    params, data = task
    fl = _cfg("fedlama")
    p_full, _ = run_training(params, _loss, data, fl, rounds=4, seed=0,
                             sampler="jax")
    p_half, l_half = run_training(params, _loss, data, fl, rounds=2, seed=0,
                                  sampler="jax")
    path = str(tmp_path / "server.npz")
    save_server_state(path, p_half, l_half.final_state)
    p_loaded, state_loaded = load_server_state(path)
    p_res, _ = run_training(p_loaded, _loss, data, fl, rounds=2, seed=0,
                            sampler="jax", start_round=2,
                            server_state=state_loaded)
    _assert_trees_equal(p_full, p_res)


def test_save_load_stateless_round_trip(tmp_path):
    params = _mlp_params()
    path = str(tmp_path / "plain.npz")
    save_server_state(path, params)
    p2, state = load_server_state(path)
    _assert_trees_equal(params, p2)
    assert state is None


# ----------------------------------------------------------------------
# FedLAMA
# ----------------------------------------------------------------------
def test_fedlama_round0_full_sync_then_intervals_adapt(task):
    params, data = task
    fl = _cfg("fedlama", fedlama_tau=2, fedlama_lam=3)
    _, log = run_training(params, _loss, data, fl, rounds=5, seed=0,
                          sampler="jax")
    g = log.final_state["global"]
    intervals = np.asarray(g["interval"])
    tau, lam = 2.0, 3.0
    assert set(np.unique(intervals)) <= {tau, tau * lam}
    assert (intervals == tau * lam).any(), \
        "no unit was demoted to the long interval"
    disc = np.asarray(g["disc"])
    assert (disc > 0).all(), "discrepancy estimate never bootstrapped"
    # uplink stays below FedAvg: only expired units travel + feedback
    assert log.meter.savings_frac > 0.2


def test_fedlama_first_round_selection_is_full(task):
    from repro.core.units import UnitMap
    params, _ = task
    umap = UnitMap.build(params)
    fl = _cfg("fedlama")
    key = jax.random.PRNGKey(0)
    batch = {"images": jax.random.normal(key, (K, 8, 32, 32, 3)),
             "labels": jax.random.randint(key, (K, 8), 0, 10)}
    sizes = jnp.full((K,), 10.0)
    strat = make_strategy(fl)
    state = strat.init_state(params, N_CLIENTS)
    _, metrics = jax.jit(build_round_fn(_loss, umap, fl))(
        params, batch, sizes, key, state)
    assert float(np.asarray(metrics["selection"]).min()) == 1.0
    # ttl advanced: nothing should sync again next round with tau >= 2
    ttl = np.asarray(metrics["state"]["global"]["ttl"])
    assert (ttl > 0).all()


def test_fedlama_drivers_agree(task):
    params, data = task
    kw = dict(fedlama_tau=2, fedlama_lam=2)
    ph, lh = run_training(params, _loss, data, _cfg("fedlama", **kw),
                          rounds=4, seed=0, sampler="jax")
    ps, ls = run_training_scan(params, _loss, data, _cfg("fedlama", **kw),
                               rounds=4, seed=0)
    pm, lm = run_training(params, _loss, data,
                          _cfg("fedlama", mode="scan", **kw),
                          rounds=4, seed=0, sampler="jax")
    _assert_trees_equal(ph, ps, atol=2e-5)
    _assert_trees_equal(ph, pm, atol=2e-5)
    _assert_trees_equal(lh.final_state, ls.final_state, atol=1e-6)
    _assert_trees_equal(lh.final_state, lm.final_state, atol=1e-6)
    assert lh.meter.uplink_bytes == pytest.approx(ls.meter.uplink_bytes,
                                                  rel=1e-6)


def test_fedlama_quantized_composition(task):
    """FedLAMA under the quantize wrapper: interval state and the upload
    transform compose (state flows through QuantizedUpload delegation)."""
    params, data = task
    fl = _cfg("fedlama", quantize_bits=8)
    _, log = run_training_scan(params, _loss, data, fl, rounds=3, seed=0)
    assert log.final_state is not None
    assert float(log.final_state["global"]["rounds"]
                 if "rounds" in log.final_state["global"]
                 else log.final_state["global"]["disc"].sum()) >= 0.0
    assert all(np.isfinite(l) for l in log.losses)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
