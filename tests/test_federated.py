"""Federated runtime: mode equivalence, algorithm semantics, e2e training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.units import UnitMap
from repro.data import (FederatedData, dirichlet_partition, iid_partition,
                        make_image_dataset)
from repro.federated import FLConfig, build_round_fn, run_training
from repro.models import cnn

CFG = cnn.VGGConfig().reduced()


def _loss(params, batch):
    return cnn.classify_loss(params, CFG, batch)


@pytest.fixture(scope="module")
def setup():
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    umap = UnitMap.build(params)
    k = 6
    key = jax.random.PRNGKey(3)
    batch = {"images": jax.random.normal(key, (k, 8, 32, 32, 3)),
             "labels": jax.random.randint(key, (k, 8), 0, 10)}
    sizes = jnp.array([10.0, 20.0, 30.0, 10.0, 15.0, 25.0])
    return params, umap, batch, sizes, key, k


@pytest.mark.parametrize("algo", ["fedldf", "fedavg", "random", "hdfl",
                                  "fedlp"])
def test_vmap_scan_equivalence(setup, algo):
    """The two execution layouts are semantically identical."""
    params, umap, batch, sizes, key, k = setup
    fv = FLConfig(algo=algo, clients_per_round=k, top_n=2, mode="vmap")
    fs = FLConfig(algo=algo, clients_per_round=k, top_n=2, mode="scan")
    pv, mv = jax.jit(build_round_fn(_loss, umap, fv))(params, batch, sizes, key)
    ps, ms = jax.jit(build_round_fn(_loss, umap, fs))(params, batch, sizes, key)
    for a, b in zip(jax.tree.leaves(pv), jax.tree.leaves(ps)):
        np.testing.assert_allclose(a, b, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(mv["selection"]),
                                  np.asarray(ms["selection"]))


def test_fedldf_nK_equals_fedavg(setup):
    """Theorem 1 degeneracy: n = K ⇒ FedLDF ≡ FedAvg exactly."""
    params, umap, batch, sizes, key, k = setup
    f1 = FLConfig(algo="fedldf", clients_per_round=k, top_n=k, mode="vmap")
    f2 = FLConfig(algo="fedavg", clients_per_round=k, top_n=k, mode="vmap")
    p1, _ = jax.jit(build_round_fn(_loss, umap, f1))(params, batch, sizes, key)
    p2, _ = jax.jit(build_round_fn(_loss, umap, f2))(params, batch, sizes, key)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_comm_savings_ratio(setup):
    """n/K = 1/3 ⇒ ~2/3 uplink saving (plus tiny feedback)."""
    params, umap, batch, sizes, key, k = setup
    fl = FLConfig(algo="fedldf", clients_per_round=k, top_n=2, mode="vmap")
    _, m = jax.jit(build_round_fn(_loss, umap, fl))(params, batch, sizes, key)
    assert float(m["comm"]["savings_frac"]) == pytest.approx(2 / 3, abs=0.01)


def test_fedadp_runs_and_prunes(setup):
    params, umap, batch, sizes, key, k = setup
    fl = FLConfig(algo="fedadp", clients_per_round=k, fedadp_keep=0.25,
                  mode="vmap")
    p, m = jax.jit(build_round_fn(_loss, umap, fl))(params, batch, sizes, key)
    assert np.isfinite(float(m["loss"]))
    assert float(m["comm"]["savings_frac"]) == pytest.approx(0.75, abs=0.01)
    # the metrics dict must stay internally consistent: FedADP overwrites
    # the total, so the payload has to be recomputed with it (regression:
    # uplink_payload used to stay at the full-participation value)
    c = m["comm"]
    assert float(c["uplink_payload"]) + float(c["uplink_feedback"]) == \
        pytest.approx(float(c["uplink_total"]))
    assert float(c["uplink_payload"]) == \
        pytest.approx(0.25 * float(c["fedavg_uplink"]))
    # scan mode (unlocked by the strategy refactor): the engine stacks the
    # sequentially-trained locals and feeds the same aggregate hook, so
    # the two layouts agree on a fixed seed.
    fl_scan = FLConfig(algo="fedadp", clients_per_round=k, fedadp_keep=0.25,
                       mode="scan")
    ps, ms = jax.jit(build_round_fn(_loss, umap, fl_scan))(params, batch,
                                                           sizes, key)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(ps)):
        np.testing.assert_allclose(a, b, atol=2e-5)
    assert float(ms["comm"]["savings_frac"]) == pytest.approx(0.75, abs=0.01)


def test_selection_favors_divergent_clients(setup):
    """A client trained with 10× LR diverges more → always selected."""
    params, umap, batch, sizes, key, k = setup
    # emulate by duplicating one client's batch with amplified labels noise:
    # instead, directly check: run round, confirm argmax-divergence clients
    # are the selected ones (uses metrics from a fedldf round).
    fl = FLConfig(algo="fedldf", clients_per_round=k, top_n=2, mode="vmap",
                  lr=0.05)
    _, m = jax.jit(build_round_fn(_loss, umap, fl))(params, batch, sizes, key)
    sel = np.asarray(m["selection"])
    np.testing.assert_array_equal(sel.sum(0), 2)


# ----------------------------------------------------------------------
@pytest.mark.slow
def test_end_to_end_training_improves():
    """20 FedLDF rounds on synthetic images reduce test error below chance."""
    train, test = make_image_dataset(num_train=2000, num_test=400, seed=1)
    parts = iid_partition(train.ys, 10, seed=0)
    fl = FLConfig(algo="fedldf", num_clients=10, clients_per_round=5,
                  top_n=2, lr=0.08, mode="vmap", batch_per_client=32)
    data = FederatedData(train.xs, train.ys, parts)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)

    test_batch = {"images": jnp.asarray(test.xs), "labels": jnp.asarray(test.ys)}
    eval_fn = jax.jit(lambda p: 1.0 - cnn.accuracy(p, CFG, test_batch))
    params, log = run_training(params, _loss, data, fl, rounds=20,
                               eval_fn=eval_fn, eval_every=19, seed=0)
    first_err = log.test_errors[0][1]
    last_err = log.test_errors[-1][1]
    assert last_err < 0.9  # well below chance + initial
    assert last_err <= first_err + 0.02
    assert log.meter.savings_frac > 0.5


def test_dirichlet_partition_properties():
    labels = np.random.default_rng(0).integers(0, 10, size=5000)
    parts = dirichlet_partition(labels, 20, alpha=1.0, seed=0)
    assert len(parts) == 20
    all_idx = np.concatenate(parts)
    assert len(all_idx) == 5000
    assert len(np.unique(all_idx)) == 5000
    sizes = np.array([len(p) for p in parts])
    assert sizes.min() >= 8
    assert sizes.std() > 0  # non-uniform sizes (paper's non-IID setting)


def test_iid_partition_uniform():
    labels = np.zeros(1000)
    parts = iid_partition(labels, 10, seed=0)
    assert all(len(p) == 100 for p in parts)
