"""§Perf variant machinery: config transforms + sharding overrides."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as sh
from repro.launch.shapes import params_struct
from repro.launch.variants import VARIANTS, apply_variant


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


def test_remat_variant_sets_flag():
    cfg, ov = apply_variant("remat", get_config("qwen3-1.7b"), ("data",))
    assert cfg.remat_blocks and ov is None


def test_flash_tune_variant():
    cfg, _ = apply_variant("remat+flash_tune", get_config("qwen2-7b"),
                           ("data",))
    assert cfg.attn_chunk == 4096 and cfg.attn_probs_bf16 and cfg.remat_blocks


@pytest.mark.parametrize("variant", ["megatron", "expert_parallel",
                                     "ssm_proj", "cache_batch"])
def test_override_specs_apply_and_divide(variant):
    """Every override must produce shardings whose dims divide the mesh for
    the arch families it targets (the dry-run enforces this for real)."""
    arch = {"megatron": "deepseek-coder-33b",
            "expert_parallel": "llama4-maverick-400b-a17b",
            "ssm_proj": "mamba2-780m",
            "cache_batch": "qwen2.5-14b"}[variant]
    cfg, ov = apply_variant(variant, get_config(arch), ("data",))
    if variant == "cache_batch":
        return  # cache overrides are validated in the decode dry-run
    ps = params_struct(cfg)
    specs = sh.param_specs(ps, MESH, overrides=ov)

    def flat(tree, prefix=""):
        if isinstance(tree, dict):
            for k, v in tree.items():
                yield from flat(v, f"{prefix}{k}/")
        else:
            yield prefix.rstrip("/"), tree

    spec_map = dict(flat(specs))
    leaf_map = dict(flat(jax.tree.map(lambda x: x.shape, ps)))
    for path, spec in spec_map.items():
        shape = leaf_map[path]
        for dim, ax in zip(shape, spec):
            if ax is None:
                continue
            size = 16 if isinstance(ax, str) else 256
            assert dim % size == 0, (path, shape, spec)


def test_megatron_removes_fsdp_on_contractions():
    cfg, ov = apply_variant("megatron", get_config("qwen2-7b"), ("data",))
    ps = params_struct(cfg)
    specs = sh.param_specs(ps, MESH, overrides=ov)
    # column-parallel: contraction (d_model) dim replicated
    assert specs["blocks"]["attn"]["wq"] == P(None, None, "model")
    assert specs["blocks"]["mlp"]["w_down"] == P(None, "model", None)
    assert specs["final"]["head"] == P(None, "model")


def test_all_variants_have_hypotheses():
    for name, v in VARIANTS.items():
        assert len(v.hypothesis) > 30, f"{name} lacks a real hypothesis"
