"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional [test] extra — deterministic fallbacks below
    HAVE_HYPOTHESIS = False

from repro.kernels import aggregate as ka
from repro.kernels import divergence as kd
from repro.kernels import ref

SHAPES = [(1, 1), (1, 37), (4, 1000), (8, 2048), (9, 2049), (48, 5000),
          (3, 16384), (62, 33)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_sqdiff_rowsum_matches_ref(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31))
    a = jax.random.normal(k1, shape, dtype=dtype)
    b = jax.random.normal(k2, shape, dtype=dtype)
    out = kd.sqdiff_rowsum(a, b, interpret=True)
    exp = ref.sqdiff_rowsum(a, b)
    assert out.shape == (shape[0],)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(out, exp, rtol=3e-3, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_masked_accumulate_matches_ref(shape, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    acc = jax.random.normal(k1, shape, dtype=jnp.float32)
    x = jax.random.normal(k2, shape, dtype=dtype)
    w = jax.random.normal(k3, (shape[0],))
    out = ka.masked_accumulate(acc, x, w, interpret=True)
    exp = ref.masked_accumulate(acc, x, w)
    np.testing.assert_allclose(out, exp, rtol=3e-3, atol=1e-5)


@pytest.mark.parametrize("block_r,block_c", [(8, 128), (8, 2048), (16, 512)])
def test_sqdiff_block_shape_invariance(block_r, block_c):
    """Result must not depend on the BlockSpec tiling."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    a = jax.random.normal(k1, (21, 3000))
    b = jax.random.normal(k2, (21, 3000))
    out = kd.sqdiff_rowsum(a, b, block_r=block_r, block_c=block_c,
                           interpret=True)
    np.testing.assert_allclose(out, ref.sqdiff_rowsum(a, b), rtol=1e-5)


def _check_sqdiff_rowsum_property(r, c, seed):
    """∀ shapes: kernel == Σ(a−b)² per row; zero diff → zero."""
    k = jax.random.PRNGKey(seed)
    a = jax.random.normal(k, (r, c))
    out = kd.sqdiff_rowsum(a, a, interpret=True)
    np.testing.assert_allclose(out, np.zeros(r), atol=1e-6)
    b = a + 1.0
    out2 = kd.sqdiff_rowsum(a, b, interpret=True)
    np.testing.assert_allclose(out2, np.full(r, float(c)), rtol=1e-4)


# deterministic fallback grid — covers the invariant without hypothesis
@pytest.mark.parametrize("r,c,seed", [
    (1, 1, 0), (1, 300, 1), (17, 1, 2), (5, 129, 3), (8, 257, 12345),
])
def test_sqdiff_rowsum_property_cases(r, c, seed):
    _check_sqdiff_rowsum_property(r, c, seed)


def _check_masked_accumulate_property(r, c, w0, seed):
    """w = 0 rows leave acc unchanged; w scales linearly."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    acc = jax.random.normal(k1, (r, c))
    x = jax.random.normal(k2, (r, c))
    w = jnp.full((r,), w0, dtype=jnp.float32)
    out = ka.masked_accumulate(acc, x, w, interpret=True)
    np.testing.assert_allclose(out, np.asarray(acc) + w0 * np.asarray(x),
                               rtol=1e-4, atol=1e-5)
    zero = ka.masked_accumulate(acc, x, jnp.zeros((r,)), interpret=True)
    np.testing.assert_allclose(zero, acc, atol=1e-6)


@pytest.mark.parametrize("r,c,w0,seed", [
    (1, 1, -2.0, 0), (1, 200, 0.5, 1), (9, 1, 2.0, 2), (4, 100, -0.75, 77),
    (7, 63, 1.0, 31337),
])
def test_masked_accumulate_property_cases(r, c, w0, seed):
    _check_masked_accumulate_property(r, c, w0, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(r=st.integers(1, 17), c=st.integers(1, 300),
           seed=st.integers(0, 2**31 - 1))
    def test_sqdiff_rowsum_property(r, c, seed):
        _check_sqdiff_rowsum_property(r, c, seed)

    @settings(max_examples=20, deadline=None)
    @given(r=st.integers(1, 9), c=st.integers(1, 200),
           w0=st.floats(-2, 2), seed=st.integers(0, 2**31 - 1))
    def test_masked_accumulate_property(r, c, w0, seed):
        _check_masked_accumulate_property(r, c, w0, seed)


def test_ops_dispatch_forced_pallas(monkeypatch):
    from repro.kernels import ops
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    a = jnp.ones((3, 100))
    b = jnp.zeros((3, 100))
    np.testing.assert_allclose(ops.sqdiff_rowsum(a, b), np.full(3, 100.0))
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "0")
    np.testing.assert_allclose(ops.sqdiff_rowsum(a, b), np.full(3, 100.0))
