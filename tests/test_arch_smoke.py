"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2 layers, d_model ≤ 512, ≤ 4 experts — same family wiring), run one forward
AND one FL train round (FedLDF scan mode) on CPU, assert output shapes and
no NaNs. Decode smoke (prefill + one token) runs per family as well.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.units import UnitMap
from repro.federated import FLConfig, build_round_scan
from repro.models import decode as dec
from repro.models import transformer as tf

SEQ = 24
BATCH = 2


def _batch_for(cfg, k=None):
    """Token batch (optionally client-stacked) for a reduced config."""
    key = jax.random.PRNGKey(0)
    lead = (k, BATCH) if k else (BATCH,)
    dlen = min(SEQ, 16) if cfg.is_encdec else SEQ
    b = {
        "tokens": jax.random.randint(key, lead + (dlen,), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, lead + (dlen,), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        b["enc_inputs"] = jax.random.normal(key, lead + (SEQ, cfg.frontend_dim),
                                            dtype=jnp.float32)
    if cfg.family == "vlm":
        b["embeddings"] = jax.random.normal(key, lead + (8, cfg.frontend_dim),
                                            dtype=jnp.float32)
    return b


@pytest.fixture(scope="module", params=ARCH_IDS)
def reduced(request):
    import dataclasses
    cfg = get_config(request.param).reduced()
    # float32 on CPU for numeric checks
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(1), cfg)
    return request.param, cfg, params


def test_forward_shapes_and_finite(reduced):
    arch, cfg, params = reduced
    batch = _batch_for(cfg)
    logits, aux = tf.forward(params, cfg, batch["tokens"],
                             enc_inputs=batch.get("enc_inputs"),
                             embeddings=batch.get("embeddings"))
    dlen = batch["tokens"].shape[1]
    assert logits.shape == (BATCH, dlen, cfg.vocab_size), arch
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert np.isfinite(float(aux)), arch


def test_train_round_fedldf(reduced):
    """One FedLDF round (scan mode, 3 clients, top-2) updates params, no NaN."""
    arch, cfg, params = reduced
    k = 3
    umap = UnitMap.build(params)
    flcfg = FLConfig(algo="fedldf", num_clients=4, clients_per_round=k,
                     top_n=2, lr=0.01, mode="scan")
    loss_fn = functools.partial(_loss, cfg)
    round_fn = jax.jit(build_round_scan(loss_fn, umap, flcfg))
    batch = _batch_for(cfg, k=k)
    new_params, metrics = round_fn(params, batch,
                                   jnp.ones((k,)), jax.random.PRNGKey(0))
    assert np.isfinite(float(metrics["loss"])), arch
    changed = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert changed, f"{arch}: round did not update params"
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch
    # selection has exactly top_n ones per unit column
    sel = np.asarray(metrics["selection"])
    np.testing.assert_array_equal(sel.sum(0), np.full(umap.num_units, 2))


def test_decode_smoke(reduced):
    arch, cfg, params = reduced
    batch = _batch_for(cfg)
    toks = batch["tokens"]
    lg, cache = dec.prefill(params, cfg, toks,
                            enc_inputs=batch.get("enc_inputs"),
                            embeddings=batch.get("embeddings"),
                            max_len=toks.shape[1] + 2)
    assert lg.shape == (BATCH, cfg.vocab_size)
    lg2, cache2 = dec.decode_step(params, cfg, toks[:, :1], cache)
    assert lg2.shape == (BATCH, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg2, np.float32)).all(), arch
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def _loss(cfg, params, batch):
    return tf.lm_loss(params, cfg, batch)


def test_all_archs_have_exact_assigned_dims():
    expected = {
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
    }
    for arch, (l, d, h, kv, ff, v) in expected.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (l, d, h, kv, ff, v), arch


def test_special_features():
    assert get_config("qwen3-1.7b").qk_norm
    assert get_config("qwen2.5-14b").qkv_bias
    assert get_config("qwen2-vl-2b").mrope
    assert get_config("mamba2-780m").ssm_state == 128
    assert get_config("hymba-1.5b").ssm_state == 16
    assert get_config("seamless-m4t-large-v2").encoder_layers == 24
    l4 = get_config("llama4-maverick-400b-a17b")
    assert (l4.num_experts, l4.moe_top_k) == (128, 1)
    ds = get_config("deepseek-moe-16b")
    assert (ds.num_experts, ds.num_shared_experts, ds.moe_top_k) == (64, 2, 6)
