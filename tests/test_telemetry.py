"""Round telemetry subsystem: taps, ledger, sink, profiling counters.

Pins the subsystem's three contracts:

- **zero-cost disabled path** — with ``FLConfig.telemetry=None`` (the
  default) the per-round metrics carry no tap keys and fixed-seed
  trajectories are bit-identical to telemetry-enabled runs across the
  host-vmap, jitted-scan, and mesh-sharded drivers (taps are pure extra
  outputs, never inputs);
- **driver-independent ledger schema** — both drivers emit round/eval
  records with exactly the same key set, absolute contiguous round
  indices, and a resumed (save → load → continue) run's ledger matches an
  uninterrupted run's indices gap-free, for a stateful (fedlama) and a
  stateless (fedavg) strategy;
- **no retraces across identical runs** — the compiled-callable cache
  reports zero new builds for a repeated identical ``run_training_scan``,
  and host-only telemetry knobs (ledger path, run id) don't change the
  cache key.
"""
import dataclasses
import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_server_state, save_server_state
from repro.core.units import UnitMap
from repro.data import FederatedData, iid_partition, make_image_dataset
from repro.federated import (FLConfig, TelemetryConfig, build_round_fn,
                             run_training, run_training_scan)
from repro.federated.server import _trace_flcfg
from repro.launch import monitor
from repro.launch.mesh import make_client_mesh
from repro.telemetry import (LEDGER_SCHEMA, ProgressSink, RoundLedger,
                             read_ledger, split_runs)
from repro.telemetry.profiling import (engine_cache_stats,
                                       reset_engine_cache_stats)

N_CLIENTS, K = 8, 4

needs_devices = [
    pytest.param(d, marks=pytest.mark.skipif(
        len(jax.devices()) < d,
        reason=f"needs {d} devices; set REPRO_TEST_DEVICES=8"))
    for d in (2,)
]


def _mlp_params(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    return {
        "l1": {"w": jax.random.normal(ks[0], (3072, 16)) * 0.02,
               "b": jnp.zeros((16,))},
        "head": {"w": jax.random.normal(ks[1], (16, 10)) * 0.1,
                 "b": jnp.zeros((10,))},
    }


def _loss(params, batch):
    x = batch["images"].reshape(batch["images"].shape[0], -1)
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    logits = h @ params["head"]["w"] + params["head"]["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, batch["labels"][:, None],
                                axis=-1).mean()


@pytest.fixture(scope="module")
def task():
    train, _ = make_image_dataset(num_train=320, num_test=16, seed=1)
    parts = iid_partition(train.ys, N_CLIENTS, seed=0)
    data = FederatedData(train.xs, train.ys, parts)
    return _mlp_params(), data


def _cfg(algo="fedldf", mode="vmap", **kw):
    return FLConfig(algo=algo, num_clients=N_CLIENTS, clients_per_round=K,
                    top_n=2, mode=mode, batch_per_client=8, **kw)


def _assert_bit_identical(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ======================================================================
# TelemetryConfig
# ======================================================================
def test_config_validation():
    with pytest.raises(ValueError, match="verbosity"):
        TelemetryConfig(verbosity="loud")
    with pytest.raises(ValueError, match="profile_rounds"):
        TelemetryConfig(profile_rounds=(5, 2))
    with pytest.raises(TypeError, match="telemetry"):
        _cfg(telemetry="yes")
    t = TelemetryConfig(profile_rounds=(1.0, 3.0))
    assert t.profile_rounds == (1, 3)
    assert isinstance(hash(t), int)    # jit-cache key material


def test_trace_key_drops_host_only_fields():
    a = _cfg(telemetry=TelemetryConfig(ledger_path="/tmp/a.jsonl",
                                       run_id="a", verbosity="quiet",
                                       profile_rounds=(0, 1)))
    b = _cfg(telemetry=TelemetryConfig(ledger_path="/tmp/b.jsonl",
                                       run_id="b", verbosity="human"))
    assert _trace_flcfg(a) == _trace_flcfg(b)     # no retrace between them
    c = _cfg(telemetry=TelemetryConfig(taps=False))
    assert _trace_flcfg(a) != _trace_flcfg(c)     # taps change the graph
    assert _trace_flcfg(_cfg()) == _cfg()          # None passes through


# ======================================================================
# Zero-cost disabled path / taps structure
# ======================================================================
def test_metrics_tap_keys_follow_config(task):
    params, _ = task
    batch = {"images": jnp.zeros((K, 8, 32, 32, 3)),
             "labels": jnp.zeros((K, 8), jnp.int32)}
    fn = build_round_fn(_loss, UnitMap.build(params), _cfg())
    _, metrics = fn(params, batch, jnp.ones((K,)), jax.random.PRNGKey(0))
    assert set(metrics) == {"loss", "comm", "selection"}

    fn_t = build_round_fn(_loss, UnitMap.build(params),
                          _cfg(telemetry=TelemetryConfig()))
    _, metrics_t = fn_t(params, batch, jnp.ones((K,)),
                        jax.random.PRNGKey(0))
    assert set(metrics_t) == {"loss", "comm", "selection", "taps"}
    assert {"div_mean", "div_max", "sel_count"} <= set(metrics_t["taps"])
    assert metrics_t["taps"]["div_mean"].shape == \
        (UnitMap.build(params).num_units,)


@pytest.mark.parametrize("algo", ["fedldf", "fedlama"])
def test_bit_identical_trajectories_host_and_scan(task, tmp_path, algo):
    params, data = task
    tele = TelemetryConfig(ledger_path=str(tmp_path / "l.jsonl"))
    for driver in ("host", "scan", "scan_mode"):
        if driver == "host":
            p0, _ = run_training(params, _loss, data, _cfg(algo), rounds=3,
                                 seed=0, sampler="jax")
            p1, _ = run_training(params, _loss, data,
                                 _cfg(algo, telemetry=tele), rounds=3,
                                 seed=0, sampler="jax")
        elif driver == "scan":
            p0, _ = run_training_scan(params, _loss, data, _cfg(algo),
                                      rounds=3, seed=0)
            p1, _ = run_training_scan(params, _loss, data,
                                      _cfg(algo, telemetry=tele),
                                      rounds=3, seed=0)
        else:
            p0, _ = run_training(params, _loss, data,
                                 _cfg(algo, mode="scan"), rounds=3,
                                 seed=0, sampler="jax")
            p1, _ = run_training(params, _loss, data,
                                 _cfg(algo, mode="scan", telemetry=tele),
                                 rounds=3, seed=0, sampler="jax")
        _assert_bit_identical(p0, p1)


@pytest.mark.parametrize("d", needs_devices)
def test_mesh_taps_bit_identical_and_residual_norm_matches(task, tmp_path,
                                                           d):
    """Mesh-sharded round with EF residual state: telemetry leaves the
    trajectory bit-identical, and the psum'd client-state norm tap equals
    the unsharded engine's value."""
    params, data = task
    mesh = make_client_mesh(d)
    lp_mesh, lp_flat = str(tmp_path / "mesh.jsonl"), str(tmp_path / "f.jsonl")
    kw = dict(quantize_bits=8, error_feedback=True)
    p0, _ = run_training(params, _loss, data, _cfg(mesh=mesh, **kw),
                         rounds=3, seed=0, sampler="jax")
    p1, _ = run_training(
        params, _loss, data,
        _cfg(mesh=mesh, telemetry=TelemetryConfig(ledger_path=lp_mesh),
             **kw), rounds=3, seed=0, sampler="jax")
    _assert_bit_identical(p0, p1)
    run_training(params, _loss, data,
                 _cfg(telemetry=TelemetryConfig(ledger_path=lp_flat), **kw),
                 rounds=3, seed=0, sampler="jax")
    rm = split_runs(read_ledger(lp_mesh))[0]["rounds"]
    rf = split_runs(read_ledger(lp_flat))[0]["rounds"]
    for a, b in zip(rm, rf):
        np.testing.assert_allclose(a["taps"]["state_residual_norm"],
                                   b["taps"]["state_residual_norm"],
                                   rtol=1e-4)


# ======================================================================
# Ledger: cross-driver schema equality + resume contiguity
# ======================================================================
def test_cross_driver_ledger_schema_equality(task, tmp_path):
    params, data = task
    eval_fn = lambda p: 0.5   # noqa: E731
    paths = {}
    for driver, runner in (("host", run_training),
                           ("scan", run_training_scan)):
        lp = str(tmp_path / f"{driver}.jsonl")
        kw = {"sampler": "jax"} if driver == "host" else {}
        runner(params, _loss, data,
               _cfg(telemetry=TelemetryConfig(ledger_path=lp)),
               rounds=5, eval_fn=eval_fn, eval_every=2, seed=0, **kw)
        paths[driver] = lp
    segs = {d: split_runs(read_ledger(p))[0] for d, p in paths.items()}
    # identical record key sets, tap key sets, and round indices
    assert [sorted(r) for r in segs["host"]["rounds"]] == \
        [sorted(r) for r in segs["scan"]["rounds"]]
    assert [sorted(r["taps"]) for r in segs["host"]["rounds"]] == \
        [sorted(r["taps"]) for r in segs["scan"]["rounds"]]
    assert [r["round"] for r in segs["host"]["rounds"]] == \
        [r["round"] for r in segs["scan"]["rounds"]] == list(range(5))
    # eval cadence (t % eval_every == 0 or last round) matches too
    assert [e["round"] for e in segs["host"]["evals"]] == \
        [e["round"] for e in segs["scan"]["evals"]] == [0, 2, 4]
    assert [sorted(e) for e in segs["host"]["evals"]] == \
        [sorted(e) for e in segs["scan"]["evals"]]
    # and the same comm-profile fields round for round
    assert [sorted(r["comm"]) for r in segs["host"]["rounds"]] == \
        [sorted(r["comm"]) for r in segs["scan"]["rounds"]]


@pytest.mark.parametrize("algo", ["fedlama", "fedavg"])
@pytest.mark.parametrize("driver", ["host", "scan"])
def test_ledger_resume_contiguous(task, tmp_path, algo, driver):
    """save -> load -> continue appends a ledger whose round indices are
    gap-free and identical to an uninterrupted run's."""
    params0, data = task

    def go(params, cfg, rounds, start_round=0, server_state=None):
        if driver == "host":
            return run_training(params, _loss, data, cfg, rounds=rounds,
                                seed=0, sampler="jax",
                                start_round=start_round,
                                server_state=server_state)
        return run_training_scan(params, _loss, data, cfg, rounds=rounds,
                                 seed=0, start_round=start_round,
                                 server_state=server_state)

    lp_full = str(tmp_path / "full.jsonl")
    pf, _ = go(params0,
               _cfg(algo, telemetry=TelemetryConfig(ledger_path=lp_full)),
               rounds=6)
    lp_res = str(tmp_path / "resumed.jsonl")
    cfg_res = _cfg(algo, telemetry=TelemetryConfig(ledger_path=lp_res))
    p1, log1 = go(params0, cfg_res, rounds=3)
    ckpt = str(tmp_path / "server.npz")
    save_server_state(ckpt, p1, log1.final_state)
    p_loaded, state_loaded = load_server_state(ckpt)
    p2, _ = go(p_loaded, cfg_res, rounds=3, start_round=3,
               server_state=state_loaded)
    _assert_bit_identical(pf, p2)

    full = split_runs(read_ledger(lp_full))
    res = split_runs(read_ledger(lp_res))
    assert len(full) == 1 and len(res) == 2    # one file, two segments
    full_rounds = [r["round"] for r in full[0]["rounds"]]
    res_rounds = [r["round"] for seg in res for r in seg["rounds"]]
    assert res_rounds == full_rounds == list(range(6))   # gap-free
    assert res[1]["meta"]["start_round"] == 3
    full_losses = [r["loss"] for r in full[0]["rounds"]]
    res_losses = [r["loss"] for seg in res for r in seg["rounds"]]
    np.testing.assert_array_equal(full_losses, res_losses)


def test_reader_skips_corrupt_and_newer_schema(tmp_path):
    lp = str(tmp_path / "l.jsonl")
    with RoundLedger(lp, meta={"run_id": "x"}) as led:
        led.round(0, 1.0, {"uplink_total": 1.0, "fedavg_uplink": 2.0}, 1.0)
    with open(lp, "a") as f:
        f.write("{torn json\n")
        f.write(json.dumps({"schema": LEDGER_SCHEMA + 1,
                            "kind": "round", "round": 9}) + "\n")
    recs = read_ledger(lp)
    assert [r["kind"] for r in recs] == ["run", "round"]
    # headerless files still split into a meta=None segment
    segs = split_runs([{"kind": "round", "round": 0}])
    assert len(segs) == 1 and segs[0]["meta"] is None


# ======================================================================
# Progress sink (verbosity satellite)
# ======================================================================
def test_sink_modes():
    buf = io.StringIO()
    ProgressSink("human", stream=buf).round(7, 0.5, test_error=0.25,
                                            uplink_bytes=2e6)
    ProgressSink("human", stream=buf).round(7, 0.5)
    assert buf.getvalue() == ("round    7 loss 0.5000 test_err 0.2500 "
                              "uplink 2.0MB\nround    7 loss 0.5000\n")
    buf = io.StringIO()
    ProgressSink("structured", stream=buf).round(7, 0.5, test_error=0.25)
    rec = json.loads(buf.getvalue())
    assert rec == {"kind": "progress", "round": 7, "loss": 0.5,
                   "test_error": 0.25}
    buf = io.StringIO()
    sink = ProgressSink("quiet", stream=buf)
    sink.round(7, 0.5, test_error=0.25)
    assert buf.getvalue() == "" and not sink.enabled
    # resolution: explicit verbosity beats the driver's verbose flag
    assert ProgressSink.for_run(None, True).mode == "human"
    assert ProgressSink.for_run(None, False).mode == "quiet"
    assert ProgressSink.for_run(TelemetryConfig(verbosity="structured"),
                                False).mode == "structured"
    assert ProgressSink.for_run(TelemetryConfig(verbosity="quiet"),
                                True).mode == "quiet"


def test_verbose_output_format_unchanged(task, capsys):
    """The legacy verbose=True one-liners survive the sink refactor
    byte-for-byte (humans grep these)."""
    params, data = task
    run_training(params, _loss, data, _cfg(), rounds=1,
                 eval_fn=lambda p: 0.25, eval_every=1, seed=0,
                 sampler="jax", verbose=True)
    line = capsys.readouterr().out.strip().splitlines()[-1]
    assert line.startswith("round    0 loss ")
    assert "test_err 0.2500 uplink " in line and line.endswith("MB")


# ======================================================================
# Retrace counters (regression satellite)
# ======================================================================
def test_scan_rerun_zero_recompiles(task, tmp_path):
    params, data = task
    cfg = _cfg(telemetry=TelemetryConfig(
        ledger_path=str(tmp_path / "a.jsonl")))
    run_training_scan(params, _loss, data, cfg, rounds=2, seed=0)
    reset_engine_cache_stats()
    run_training_scan(params, _loss, data, cfg, rounds=2, seed=0)
    # a config differing only in host-side fields must also hit the cache
    cfg2 = dataclasses.replace(cfg, telemetry=TelemetryConfig(
        ledger_path=str(tmp_path / "b.jsonl"), run_id="other"))
    run_training_scan(params, _loss, data, cfg2, rounds=2, seed=0)
    stats = engine_cache_stats()
    assert stats.get("block_builds", 0) == 0, stats
    assert stats.get("block_hits", 0) == 2, stats


# ======================================================================
# Monitor (consumer smoke)
# ======================================================================
def test_monitor_renders_ledger(task, tmp_path):
    params, data = task
    lp = str(tmp_path / "m.jsonl")
    run_training(params, _loss, data,
                 _cfg("fedlama",
                      telemetry=TelemetryConfig(ledger_path=lp,
                                                run_id="mon")),
                 rounds=4, eval_fn=lambda p: 0.5, eval_every=2, seed=0,
                 sampler="jax")
    buf = io.StringIO()
    assert monitor.render(lp, out=buf) == 1
    text = buf.getvalue()
    assert "run mon" in text
    assert "per-layer mean divergence" in text
    assert "per-layer uploads" in text
    assert "state_interval" in text            # fedlama global-state tap
    assert "bytes/round" in text and "eval @ round" in text
    # sparkline/binning helpers are total functions on edge inputs
    assert monitor.sparkline([]) == ""
    assert len(monitor.bin_series(np.arange(100), 10)) == 10
