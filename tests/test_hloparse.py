"""Loop-aware HLO roofline parser: validated against known-FLOP programs
(the while-body undercount of cost_analysis() is the reason this exists)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hloparse


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


X = jax.ShapeDtypeStruct((64, 128), jnp.float32)
W = jax.ShapeDtypeStruct((128, 128), jnp.float32)
MM_FLOPS = 2 * 64 * 128 * 128


def test_single_matmul():
    t = hloparse.analyze(_hlo(lambda x, w: x @ w, X, W))
    assert t.flops == pytest.approx(MM_FLOPS, rel=0.01)


def test_scan_trip_count():
    def f(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=11)[0]
    t = hloparse.analyze(_hlo(f, X, W))
    assert t.flops == pytest.approx(11 * MM_FLOPS, rel=0.01)


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            inner = jax.lax.scan(lambda c2, _: (c2 @ w, None), c, None,
                                 length=7)[0]
            return inner, None
        return jax.lax.scan(outer, x, None, length=5)[0]
    t = hloparse.analyze(_hlo(f, X, W))
    assert t.flops == pytest.approx(35 * MM_FLOPS, rel=0.01)


def test_grad_through_scan():
    def f(x, w):
        y = jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None,
                         length=6)[0]
        return jnp.sum(y * y)
    t = hloparse.analyze(_hlo(jax.grad(f, argnums=1), X, W))
    # fwd (6) + bwd dgrad (6) + bwd wgrad (6)
    assert t.flops == pytest.approx(18 * MM_FLOPS, rel=0.01)


def test_cost_analysis_undercounts_while_bodies():
    """Regression guard for the motivation: if XLA ever fixes this, we can
    simplify — the test documents the current behaviour."""
    def f(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=10)[0]
    compiled = jax.jit(f).lower(X, W).compile()
    xla_flops = hloparse.cost_analysis_dict(
        compiled.cost_analysis()).get("flops", 0.0)
    parsed = hloparse.analyze(compiled.as_text()).flops
    assert parsed == pytest.approx(10 * MM_FLOPS, rel=0.01)
    assert xla_flops <= parsed / 5  # XLA counts the body once


def test_hbm_bytes_positive_and_sane():
    t = hloparse.analyze(_hlo(lambda x, w: x @ w, X, W))
    min_traffic = (64 * 128 + 128 * 128 + 64 * 128) * 4
    assert t.hbm_bytes >= min_traffic
    assert t.hbm_bytes < 50 * min_traffic


def test_collectives_detected_on_sharded_program():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (dry-run process tests this at 512)")
