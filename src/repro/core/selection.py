"""Client-selection policies (Eq. 4 and the paper's baselines).

Every policy produces a selection matrix ``s ∈ {0,1}^{K×U}`` (clients ×
layer-units). ``s[k, u] = 1`` iff layer-unit ``u`` of client ``k`` is uploaded
and enters the Eq. 5 aggregation. All policies are jit-safe.

Policies
--------
- :func:`topn_divergence`  — FedLDF (Eq. 4): per unit, the n clients with the
  largest divergence.
- :func:`random_per_layer` — "random" baseline: per unit, n uniform clients.
- :func:`client_dropout`   — HDFL baseline [7]: n whole clients, all units.
- :func:`full_participation` — FedAvg: everything.
- :func:`bernoulli_per_layer` — FedLP (Zhu et al., arXiv:2303.06360):
  each (client, unit) kept independently with probability p.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topn_divergence(divergence: jnp.ndarray, n: int) -> jnp.ndarray:
    """Eq. 4: top-n clients per layer-unit by divergence.

    divergence: (K, U) — ΔΘ_{k,u} from Eq. 3.
    Returns s: (K, U) float32 with exactly n ones per column.
    Ties are broken by client index (jax.lax.top_k is deterministic).
    """
    k, u = divergence.shape
    if not 1 <= n <= k:
        raise ValueError(f"top-n out of range: n={n}, K={k}")
    # top_k over the client axis: work in (U, K).
    _, idx = jax.lax.top_k(divergence.T, n)          # (U, n)
    onehot = jax.nn.one_hot(idx, k, dtype=jnp.float32)  # (U, n, K)
    return onehot.sum(axis=1).T                      # (K, U)


def random_per_layer(key: jax.Array, num_clients: int, num_units: int,
                     n: int) -> jnp.ndarray:
    """Random baseline: per unit, choose n clients uniformly at random."""
    scores = jax.random.uniform(key, (num_clients, num_units))
    return topn_divergence(scores, n)


def client_dropout(key: jax.Array, num_clients: int, num_units: int,
                   n: int) -> jnp.ndarray:
    """HDFL [7]: choose n whole clients; they upload *all* units."""
    scores = jax.random.uniform(key, (num_clients,))
    _, idx = jax.lax.top_k(scores, n)
    rows = jax.nn.one_hot(idx, num_clients, dtype=jnp.float32).sum(axis=0)
    return jnp.broadcast_to(rows[:, None], (num_clients, num_units))


def full_participation(num_clients: int, num_units: int) -> jnp.ndarray:
    """FedAvg: s ≡ 1."""
    return jnp.ones((num_clients, num_units), dtype=jnp.float32)


def bernoulli_per_layer(key: jax.Array, num_clients: int, num_units: int,
                        p: float) -> jnp.ndarray:
    """FedLP layer-wise probabilistic participation: client k uploads unit
    u with probability ``p``, independently per (client, unit). Columns may
    come up empty — Eq. 5 consumers fall back to the previous global value
    for units nobody kept."""
    if not 0.0 < p <= 1.0:
        raise ValueError(f"keep probability out of range: p={p}")
    return jax.random.bernoulli(key, p, (num_clients, num_units)).astype(
        jnp.float32)
