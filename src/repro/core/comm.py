"""Communication accounting (the quantity the paper optimises).

The paper's headline result is an ~80 % reduction in *uplink* bytes: with
top-n-per-layer selection only ``n/K`` of the layer payloads travel from
clients to the server, plus a negligible divergence-feedback vector
(K · U float32 scalars per round).

`round_comm` is a pure jit-safe function of the selection matrix; the
:class:`CommMeter` accumulates totals across rounds on the host.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.units import UnitMap

DIVERGENCE_SCALAR_BYTES = 4  # float32 feedback scalars


def round_comm(selection: jnp.ndarray, umap: UnitMap, *,
               divergence_feedback: bool = True,
               param_bytes_override: float | None = None,
               unit_bytes_override: jnp.ndarray | None = None,
               axis_name: str | None = None) -> dict:
    """Per-round communication in bytes.

    selection: (K, U) ∈ {0,1}. When the round runs client-sharded
    (``shard_map`` over a ``'clients'`` mesh axis), pass the *local* rows
    plus ``axis_name``: the payload sum and client count are ``psum``'d
    across the axis, so every device returns the identical global totals —
    no all-gather of the selection matrix is needed for accounting.

    ``param_bytes_override`` reprices every parameter uniformly (legacy
    quantized pricing, e.g. 1.0 for int8).  ``unit_bytes_override`` — a
    (U,) per-unit byte vector, usually ``PackedPayload.unit_wire_bytes`` —
    takes precedence and is the packed wire format's source of truth
    (header + ceil(params·bits/8) per unit, possibly traced per round).

    Returns dict with jnp scalars:
      uplink_payload   — Σ_{k,u} s[k,u]·bytes(u)        (selected layers)
      uplink_feedback  — K·U·4 if divergence feedback is on (FedLDF only)
      uplink_total
      downlink         — K·total_model_bytes (server broadcast, unchanged
                         vs FedAvg; the paper optimises uplink)
      fedavg_uplink    — K·total_model_bytes (reference)
      savings_frac     — 1 − uplink_total/fedavg_uplink
    """
    k = selection.shape[0]
    if axis_name is not None:
        k = k * jax.lax.psum(1, axis_name)   # global K across the mesh
    if unit_bytes_override is not None:
        unit_bytes = jnp.asarray(unit_bytes_override, jnp.float32)
    else:
        scale = (1.0 if param_bytes_override is None
                 else param_bytes_override / 4.0)
        unit_bytes = umap.unit_bytes_array() * scale
    payload = jnp.sum(selection * unit_bytes[None, :])
    if axis_name is not None:
        payload = jax.lax.psum(payload, axis_name)
    feedback = jnp.float32(
        k * umap.num_units * DIVERGENCE_SCALAR_BYTES if divergence_feedback
        else 0.0)
    # reference = uncompressed FedAvg (full model, fp32 wire format)
    fedavg_up = jnp.float32(k) * jnp.float32(umap.total_bytes)
    uplink = payload + feedback
    return {
        "uplink_payload": payload,
        "uplink_feedback": feedback,
        "uplink_total": uplink,
        "downlink": fedavg_up,
        "fedavg_uplink": fedavg_up,
        "savings_frac": 1.0 - uplink / fedavg_up,
    }


def agg_tier_bytes(payload_bytes: float, axis_size: int,
                   group_size: int = 0) -> dict:
    """Per-round aggregation-traffic split for the (optionally two-tier)
    cross-device reduce (:func:`repro.core.aggregation.hierarchical_psum`).

    ``payload_bytes`` is ONE device's reduce payload P (the Eq. 5
    numerator tree riding the fused psum — on a 2-D mesh the 1/M
    'model'-axis slice). ``group_size`` of 0 or ``axis_size`` means the
    flat single psum. All values are static per configuration (pure
    topology × payload arithmetic, deliberately NOT riding the psum so the
    flat path's compiled round stays byte-identical):

      agg_payload_bytes        — P
      agg_intra_bytes          — total bytes/round on intra-group links
                                 (tier-1: non-leader partials funnel to a
                                 group leader; 0 for the flat reduce)
      agg_cross_bytes          — total bytes/round crossing group
                                 boundaries (flat: all D−1 partials funnel
                                 to the root; hier: the leaders' ring
                                 moves G·(G−1) payloads)
      agg_cross_bytes_per_host — the busiest participant's share of the
                                 cross-tier traffic, send+receive (flat:
                                 the root takes 2·(D−1)·P; hier: every
                                 ring member moves 2·(G−1)·P) — the
                                 "server bandwidth is no longer the
                                 ceiling" number
      agg_groups               — number of tier-1 groups G
      agg_tiers                — 1 (flat) or 2 (hierarchical)
    """
    d = int(axis_size)
    gs = int(group_size) or d
    if d % gs:
        raise ValueError(f"agg_tier_bytes: group_size={gs} must divide "
                         f"axis_size={d}")
    p = float(payload_bytes)
    num_groups = d // gs
    if num_groups <= 1:     # flat: one rendezvous, root absorbs everything
        return {"agg_payload_bytes": p,
                "agg_intra_bytes": 0.0,
                "agg_cross_bytes": (d - 1) * p,
                "agg_cross_bytes_per_host": 2.0 * (d - 1) * p,
                "agg_groups": 1.0, "agg_tiers": 1.0}
    return {"agg_payload_bytes": p,
            "agg_intra_bytes": float(d - num_groups) * p,
            "agg_cross_bytes": float(num_groups * (num_groups - 1)) * p,
            "agg_cross_bytes_per_host": 2.0 * (num_groups - 1) * p,
            "agg_groups": float(num_groups), "agg_tiers": 2.0}


# ----------------------------------------------------------------------
# Device-side accumulator (scan engine): a dict of float32 scalars that
# lives in the lax.scan carry, so no per-round device→host pull is needed.
# ----------------------------------------------------------------------
def comm_acc_init() -> dict:
    """Zeroed jit-safe accumulator matching :class:`CommMeter`'s totals."""
    z = jnp.float32(0.0)
    return {"uplink_bytes": z, "downlink_bytes": z,
            "fedavg_uplink_bytes": z, "rounds": z}


def comm_acc_update(acc: dict, round_stats: dict) -> dict:
    """Pure functional accumulate of one round's :func:`round_comm` stats."""
    return {
        "uplink_bytes": acc["uplink_bytes"] + round_stats["uplink_total"],
        "downlink_bytes": acc["downlink_bytes"] + round_stats["downlink"],
        "fedavg_uplink_bytes": (acc["fedavg_uplink_bytes"]
                                + round_stats["fedavg_uplink"]),
        "rounds": acc["rounds"] + 1.0,
    }


@dataclasses.dataclass
class CommMeter:
    """Host-side cumulative communication meter."""

    uplink_bytes: float = 0.0
    downlink_bytes: float = 0.0
    fedavg_uplink_bytes: float = 0.0
    rounds: int = 0

    def update(self, round_stats: dict) -> None:
        self.uplink_bytes += float(round_stats["uplink_total"])
        self.downlink_bytes += float(round_stats["downlink"])
        self.fedavg_uplink_bytes += float(round_stats["fedavg_uplink"])
        self.rounds += 1

    @classmethod
    def from_accumulator(cls, acc: dict) -> "CommMeter":
        """One device→host pull at the end of a scanned training run."""
        return cls(uplink_bytes=float(acc["uplink_bytes"]),
                   downlink_bytes=float(acc["downlink_bytes"]),
                   fedavg_uplink_bytes=float(acc["fedavg_uplink_bytes"]),
                   rounds=int(acc["rounds"]))

    @property
    def savings_frac(self) -> float:
        if self.fedavg_uplink_bytes == 0:
            return 0.0
        return 1.0 - self.uplink_bytes / self.fedavg_uplink_bytes

    def summary(self) -> dict:
        return {
            "rounds": self.rounds,
            "uplink_MB": self.uplink_bytes / 1e6,
            "downlink_MB": self.downlink_bytes / 1e6,
            "fedavg_uplink_MB": self.fedavg_uplink_bytes / 1e6,
            "uplink_savings_frac": self.savings_frac,
        }
