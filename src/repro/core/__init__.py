"""FedLDF core: the paper's contribution as composable JAX modules."""
from repro.core import (aggregation, comm, compress, convergence, fedadp,
                        lowrank, partition, selection, units, wire)
from repro.core.aggregation import (aggregate_stacked, fedavg_stacked,
                                    hierarchical_psum, streaming_add,
                                    streaming_finalize, streaming_init,
                                    unit_weights)
from repro.core.comm import CommMeter, agg_tier_bytes, round_comm
from repro.core.convergence import BoundParams, asymptotic_gap, contraction_A
from repro.core.partition import ParamPartition, partition_counts
from repro.core.selection import (client_dropout, full_participation,
                                  random_per_layer, topn_divergence)
from repro.core.units import UnitMap
from repro.core.wire import (UNIT_HEADER_BYTES, CompressionConfig,
                             PackedPayload, allocate_bits)

__all__ = [
    "aggregation", "comm", "compress", "convergence", "fedadp", "lowrank",
    "partition", "selection", "units", "wire",
    "aggregate_stacked", "fedavg_stacked", "hierarchical_psum",
    "streaming_add", "streaming_finalize", "streaming_init", "unit_weights",
    "CommMeter", "agg_tier_bytes", "round_comm", "BoundParams",
    "asymptotic_gap",
    "contraction_A", "client_dropout", "full_participation",
    "random_per_layer", "topn_divergence", "ParamPartition", "UnitMap",
    "UNIT_HEADER_BYTES", "CompressionConfig", "PackedPayload",
    "allocate_bits", "partition_counts",
]
