"""Beyond-paper: quantized delta upload with error feedback.

The paper reduces uplink by a factor n/K via layer selection. Orthogonally,
each *selected* layer can be uploaded as a quantized **delta** against the
broadcast global model (the client already holds Ĝ^t):

    upload_k = Q_b(Θ_k − Ĝ + e_k),   e_k' = (Θ_k − Ĝ + e_k) − Q_b(...)

with symmetric per-layer-unit int-b quantization Q_b and client-side error
feedback e_k (residuals carried across rounds so quantization noise averages
out instead of accumulating). The server reconstructs Θ̂_k = Ĝ + dequant and
aggregates with Eq. 5 unchanged. Uplink becomes `n/K · b/32` of FedAvg —
e.g. n/K=0.2 with int8 ⇒ 95 % total reduction.

Composability with FedLDF is the point: selection is per layer, quantization
is per layer, and the divergence feedback (Eq. 3) is computed on the
*unquantized* local model, so the protocol is unchanged upstream.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.units import UnitMap, tree_sub

Pytree = Any


def quantize_unit_symmetric(delta: Pytree, umap: UnitMap, bits: int
                            ) -> tuple[Pytree, jnp.ndarray]:
    """Symmetric per-unit quantization. Returns (int levels as float pytree,
    per-unit scales (U,)). Levels ∈ [−(2^{b−1}−1), 2^{b−1}−1]."""
    qmax = float(2 ** (bits - 1) - 1)
    maxabs = jnp.zeros((umap.num_units,), jnp.float32)
    for key, (off, n) in umap.spans.items():
        for leaf in jax.tree.leaves(delta[key]):
            flat = jnp.abs(leaf.astype(jnp.float32)).reshape(
                (n, -1) if n > 1 else (1, -1)).max(axis=1)
            seg = jax.lax.dynamic_slice(maxabs, (off,), (n,))
            maxabs = jax.lax.dynamic_update_slice(
                maxabs, jnp.maximum(seg, flat), (off,))
    scales = jnp.maximum(maxabs, 1e-12) / qmax

    inv = 1.0 / scales

    def q_key(key):
        off, n = umap.spans[key]
        seg = jax.lax.dynamic_slice(inv, (off,), (n,))

        def q(leaf):
            s = seg.reshape((n,) + (1,) * (leaf.ndim - 1)) if n > 1 else seg[0]
            return jnp.round(jnp.clip(leaf.astype(jnp.float32) * s,
                                      -qmax, qmax))

        return jax.tree.map(q, delta[key])

    return {k: q_key(k) for k in delta}, scales


def dequantize_unit(levels: Pytree, umap: UnitMap,
                    scales: jnp.ndarray) -> Pytree:
    def dq_key(key):
        off, n = umap.spans[key]
        seg = jax.lax.dynamic_slice(scales, (off,), (n,))

        def dq(leaf):
            s = seg.reshape((n,) + (1,) * (leaf.ndim - 1)) if n > 1 else seg[0]
            return leaf * s

        return jax.tree.map(dq, levels[key])

    return {k: dq_key(k) for k in levels}


def compress_upload(local: Pytree, global_params: Pytree, umap: UnitMap,
                    bits: int, residual: Optional[Pytree] = None
                    ) -> tuple[Pytree, Pytree]:
    """Client-side: returns (Θ̂ as the server reconstructs it, new residual).

    Θ̂ = Ĝ + dequant(Q(Δ + e));  e' = (Δ + e) − dequant(Q(Δ + e)).
    """
    delta = tree_sub(local, global_params)
    if residual is not None:
        delta = jax.tree.map(
            lambda d, e: d + e.astype(d.dtype), delta, residual)
    levels, scales = quantize_unit_symmetric(delta, umap, bits)
    recon_delta = dequantize_unit(levels, umap, scales)
    new_residual = jax.tree.map(
        lambda d, r: d.astype(jnp.float32) - r, delta, recon_delta)
    theta_hat = jax.tree.map(
        lambda g, r: (g.astype(jnp.float32) + r).astype(g.dtype),
        global_params, recon_delta)
    return theta_hat, new_residual


def quantized_bytes_per_param(bits: int) -> float:
    """Payload bytes per parameter (levels only; scales are U floats,
    negligible) — feeds CommMeter's param_bytes_override."""
    return bits / 8.0
