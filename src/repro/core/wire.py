"""Packed uplink wire format: what a compressed upload actually is.

Before this module the quantize→error-feedback→aggregate chain operated on
full fp32 pytrees — the "wire format" never existed in memory, so both
compute and bandwidth accounting paid fp32 prices.  :class:`PackedPayload`
makes it real: per-unit symmetric-quantized **levels** stored as int8 (or
int4 nibble pairs when every bit-width fits in 4), per-unit fp32 **scales**,
and a per-unit **bit-width vector**.  ``nbytes``/``unit_wire_bytes`` are the
single source of truth for comm accounting (``core/comm`` consumes them via
``unit_bytes_override``), and the packed buffers are exactly what the fused
uplink kernel (``kernels/uplink.py``) streams through VMEM.

Bit-widths may be **adaptive**: ``CompressionConfig(bits="auto")`` turns on
rate-distortion waterfilling (:func:`allocate_bits`) over the per-layer
divergence statistics FedLDF already computes (Eq. 3) — layers whose clients
diverge more get more bits under a mean-bits budget (analysis: Federated
Learning with Lossy Distributed Source Coding, arXiv:2204.10985).  The
allocation is jit-safe: buffer shapes stay static (storage is int8), only
the traced logical bit-width vector changes per round.

Per-unit wire cost is ``ceil(params·bits/8)`` level bytes plus a
:data:`UNIT_HEADER_BYTES` header (one fp32 scale + one bit-width byte).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.units import UnitMap

Pytree = Any

# per-unit wire header: one fp32 scale + one bit-width byte
UNIT_HEADER_BYTES = 5
_EPS = 1e-20


# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Uplink compression policy (``FLConfig.compression``).

    bits            int 2..8 for a fixed width, or ``"auto"`` for
                    divergence-driven per-layer allocation.
    error_feedback  carry client-side quantization residuals across rounds.
    allocation      bit-allocation policy when ``bits == "auto"``
                    (only ``"waterfill"`` today).
    avg_bits        mean-bits-per-param budget for ``"auto"``.
    min_bits/max_bits  clamp range for allocated widths.
    fused           route through the packed wire format + fused uplink
                    kernel; ``False`` keeps the legacy unfused fp32 chain
                    (kept as the A/B reference — see ``kernel_bench``).
    """
    bits: Union[int, str] = 8
    error_feedback: bool = False
    allocation: str = "waterfill"
    avg_bits: float = 4.0
    min_bits: int = 2
    max_bits: int = 8
    fused: bool = True

    def __post_init__(self):
        if isinstance(self.bits, str):
            if self.bits != "auto":
                raise ValueError(
                    f"CompressionConfig.bits must be an int in [2, 8] or "
                    f"'auto', got {self.bits!r}")
        elif not 2 <= int(self.bits) <= 8:
            raise ValueError(
                f"CompressionConfig.bits must be in [2, 8], got {self.bits}")
        if self.allocation != "waterfill":
            raise ValueError(
                f"unknown bit-allocation policy {self.allocation!r} "
                "(supported: 'waterfill')")
        if not 1 <= self.min_bits <= self.max_bits <= 8:
            raise ValueError(
                f"need 1 <= min_bits <= max_bits <= 8, got "
                f"[{self.min_bits}, {self.max_bits}]")
        if self.is_auto and not self.min_bits <= self.avg_bits <= self.max_bits:
            raise ValueError(
                f"avg_bits={self.avg_bits} outside "
                f"[min_bits={self.min_bits}, max_bits={self.max_bits}]")
        if self.is_auto and not self.fused:
            raise ValueError(
                "bits='auto' needs the packed wire format (fused=True); "
                "the legacy unfused chain only supports a fixed width")

    @property
    def is_auto(self) -> bool:
        return self.bits == "auto"

    @property
    def storage_bits(self) -> int:
        """Physical level storage: int4 nibble pairs when every possible
        width fits in 4 bits, else int8.  Static — jit shapes depend on it."""
        if self.is_auto:
            return 4 if self.max_bits <= 4 else 8
        return 4 if int(self.bits) <= 4 else 8

    def bits_vector(self, umap: UnitMap,
                    divs: jnp.ndarray | None = None) -> jnp.ndarray:
        """(U,) f32 logical bit-widths — constant for fixed ``bits``,
        waterfilled from the (K, U) divergence stats for ``"auto"``."""
        if not self.is_auto:
            return jnp.full((umap.num_units,), float(int(self.bits)),
                            jnp.float32)
        if divs is None:
            raise ValueError("bits='auto' needs divergence stats")
        return allocate_bits(divs, umap, avg_bits=self.avg_bits,
                             min_bits=self.min_bits, max_bits=self.max_bits)


# ----------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedPayload:
    """One (or a stacked batch of) packed uplink payload(s).

    levels   pytree matching the model structure; int8 leaves holding the
             quantized levels (two int4 nibbles per byte along the last
             axis when ``storage_bits == 4``).
    scales   (..., U) fp32 per-unit dequantization scales.
    bits     (U,) fp32 per-unit logical bit-widths.
    storage_bits  static physical width of the level buffers (8 or 4).

    Registered as a pytree, so payloads vmap/psum/shard like any leaf —
    packed buffers slice along the 'model' mesh axis exactly as the fp32
    params they stand in for.
    """
    levels: Pytree
    scales: jnp.ndarray
    bits: jnp.ndarray
    storage_bits: int = 8

    def tree_flatten(self):
        return (self.levels, self.scales, self.bits), (self.storage_bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        levels, scales, bits = children
        return cls(levels, scales, bits, storage_bits=aux[0])

    @property
    def nbytes(self) -> int:
        """Physical packed size in bytes (static): int8 level buffers count
        one byte per element (nibble packing already halved them), plus the
        fp32 scales and one byte per bit-width entry."""
        lv = sum(int(np.prod(leaf.shape))
                 for leaf in jax.tree.leaves(self.levels))
        return (lv + 4 * int(np.prod(self.scales.shape))
                + int(np.prod(self.bits.shape)))

    def unit_wire_bytes(self, umap: UnitMap) -> jnp.ndarray:
        """(U,) f32 logical wire bytes per unit under the *allocated* widths:
        ``ceil(params·bits/8) + UNIT_HEADER_BYTES``.  This — not fp32 unit
        sizes — is what ``core/comm`` charges for a packed upload."""
        p = jnp.asarray(umap.unit_params, jnp.float32)
        return jnp.ceil(p * self.bits / 8.0) + UNIT_HEADER_BYTES


# ----------------------------------------------------------------------
# quantization with per-unit bit widths (generalizes core/compress to a
# traced (U,) bits vector; identical math to quantize_unit_symmetric when
# the vector is constant)

def quantize_units(delta: Pytree, umap: UnitMap, bits: jnp.ndarray
                   ) -> tuple[Pytree, jnp.ndarray]:
    """Symmetric per-unit quantization under per-unit widths.

    Returns (int levels as f32 pytree in [−qmax_u, qmax_u], scales (U,)).
    """
    qmax = jnp.exp2(bits.astype(jnp.float32) - 1.0) - 1.0
    maxabs = jnp.zeros((umap.num_units,), jnp.float32)
    for key, (off, n) in umap.spans.items():
        for leaf in jax.tree.leaves(delta[key]):
            flat = jnp.abs(leaf.astype(jnp.float32)).reshape(
                (n, -1) if n > 1 else (1, -1)).max(axis=1)
            seg = jax.lax.dynamic_slice(maxabs, (off,), (n,))
            maxabs = jax.lax.dynamic_update_slice(
                maxabs, jnp.maximum(seg, flat), (off,))
    scales = jnp.maximum(maxabs, 1e-12) / qmax
    inv = 1.0 / scales

    def q_key(key):
        off, n = umap.spans[key]
        seg_i = jax.lax.dynamic_slice(inv, (off,), (n,))
        seg_q = jax.lax.dynamic_slice(qmax, (off,), (n,))

        def q(leaf):
            shape = (n,) + (1,) * (leaf.ndim - 1)
            if n > 1:
                s, qm = seg_i.reshape(shape), seg_q.reshape(shape)
            else:
                s, qm = seg_i[0], seg_q[0]
            return jnp.round(jnp.clip(leaf.astype(jnp.float32) * s, -qm, qm))

        return jax.tree.map(q, delta[key])

    return {k: q_key(k) for k in delta}, scales


# ----------------------------------------------------------------------
# int4 nibble packing (last axis; odd tails zero-padded)

def _pack4(levels_i8: jnp.ndarray) -> jnp.ndarray:
    c = levels_i8.shape[-1]
    if c % 2:
        pad = [(0, 0)] * (levels_i8.ndim - 1) + [(0, 1)]
        levels_i8 = jnp.pad(levels_i8, pad)
    u = (levels_i8.astype(jnp.int16) + 8).astype(jnp.uint8)  # [-7,7] -> 1..15
    lo, hi = u[..., 0::2], u[..., 1::2]
    return jax.lax.bitcast_convert_type(lo | (hi << 4), jnp.int8)


def _unpack4(packed_i8: jnp.ndarray, c: int) -> jnp.ndarray:
    b = jax.lax.bitcast_convert_type(packed_i8, jnp.uint8)
    lo = (b & 0xF).astype(jnp.int16) - 8
    hi = (b >> 4).astype(jnp.int16) - 8
    out = jnp.stack([lo, hi], axis=-1).reshape(b.shape[:-1] + (-1,))
    return out[..., :c].astype(jnp.int8)


def pack_levels(levels: Pytree, storage_bits: int = 8) -> Pytree:
    """Quantized levels pytree → physical wire buffers: int8 verbatim, or
    int4 nibble pairs along the last axis when ``storage_bits == 4``."""
    if storage_bits == 4:
        return jax.tree.map(lambda l: _pack4(l.astype(jnp.int8)), levels)
    return jax.tree.map(lambda l: l.astype(jnp.int8), levels)


def pack(delta: Pytree, umap: UnitMap, bits: jnp.ndarray,
         storage_bits: int = 8) -> PackedPayload:
    """Quantize ``delta`` under the per-unit ``bits`` vector and pack the
    levels into int8 (or int4 nibble-pair) buffers."""
    levels, scales = quantize_units(delta, umap, bits)
    return PackedPayload(pack_levels(levels, storage_bits), scales, bits,
                         storage_bits=storage_bits)


def unpack_levels(payload: PackedPayload, ref: Pytree) -> Pytree:
    """Unpacked int8 levels, shaped like ``ref`` (the model pytree the
    payload was packed from — needed to recover odd last-dim sizes)."""
    if payload.storage_bits != 4:
        return payload.levels
    return jax.tree.map(lambda lv, r: _unpack4(lv, r.shape[-1]),
                        payload.levels, ref)


def dequantize(payload: PackedPayload, umap: UnitMap, ref: Pytree) -> Pytree:
    """f32 delta reconstruction ``levels · scales`` (unfused reference —
    the fused kernel in ``kernels/uplink.py`` never materializes this)."""
    levels = unpack_levels(payload, ref)

    def dq_key(key):
        off, n = umap.spans[key]
        seg = jax.lax.dynamic_slice(payload.scales, (off,), (n,))

        def dq(leaf):
            s = seg.reshape((n,) + (1,) * (leaf.ndim - 1)) if n > 1 else seg[0]
            return leaf.astype(jnp.float32) * s

        return jax.tree.map(dq, levels[key])

    return {k: dq_key(k) for k in levels}


# ----------------------------------------------------------------------
def allocate_bits(divs: jnp.ndarray, umap: UnitMap, *,
                  avg_bits: float = 4.0, min_bits: int = 2,
                  max_bits: int = 8, iters: int = 40) -> jnp.ndarray:
    """Reverse-waterfilling bit allocation from divergence statistics.

    Per-unit distortion proxy: the clients' mean squared divergence per
    parameter (Eq. 3 stats normalized by unit size).  The rate-distortion
    shape ``b_u = clip(λ + ½log₂ σ²_u, min, max)`` is monotone in the water
    level λ, so a fixed-count bisection (jit-safe: no data-dependent trip
    count) finds the largest λ whose parameter-weighted mean stays within
    ``avg_bits``; widths are floored to integers, which can only land the
    budget lower.  Uniform per-parameter divergence energy ⇒ every unit
    gets ``avg_bits``; units whose clients diverge more per parameter get
    proportionally more bits.
    """
    p = jnp.asarray(umap.unit_params, jnp.float32)
    d = divs.astype(jnp.float32)
    if d.ndim == 2:
        d = jnp.mean(jnp.square(d), axis=0)
    else:
        d = jnp.square(d)
    r = 0.5 * jnp.log2(jnp.maximum(d / jnp.maximum(p, 1.0), _EPS))
    lo = jnp.float32(min_bits) - jnp.max(r)
    hi = jnp.float32(max_bits) - jnp.min(r)
    psum = jnp.sum(p)

    def mean_bits(lam):
        return jnp.sum(p * jnp.clip(lam + r, min_bits, max_bits)) / psum

    def body(_, bounds):
        blo, bhi = bounds
        mid = 0.5 * (blo + bhi)
        over = mean_bits(mid) > avg_bits
        return jnp.where(over, blo, mid), jnp.where(over, mid, bhi)

    lam, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    b = jnp.clip(lam + r, min_bits, max_bits)
    return jnp.floor(b + 1e-4)
