"""FedADP baseline [6]: adaptive pruning with the *neuron* as pruning unit.

Each client uploads only its most-changed neurons (rows of weight matrices /
conv output channels); the server aggregates element-wise over the uploaded
entries. This is the finer-granularity comparison point the paper contrasts
with FedLDF's layer-granularity selection (paper §III, pruning ratio chosen
for equal communication overhead).

Implemented for the stacked (vmap-client) layout used by the CIFAR-scale
experiments.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def _neuron_axis_scores(delta: jnp.ndarray) -> jnp.ndarray:
    """Importance per output-neuron (last axis) = L2 over all other axes."""
    if delta.ndim == 1:
        return jnp.abs(delta)
    axes = tuple(range(delta.ndim - 1))
    return jnp.sqrt(jnp.sum(delta.astype(jnp.float32) ** 2, axis=axes))


def neuron_masks(client_params: Pytree, global_params: Pytree,
                 keep_frac: float) -> Pytree:
    """Per-leaf {0,1} masks keeping the top ``keep_frac`` of output neurons
    by update magnitude. client_params leaves have NO client axis here
    (call under vmap)."""

    def mask_leaf(theta, g):
        delta = theta.astype(jnp.float32) - g.astype(jnp.float32)
        scores = _neuron_axis_scores(delta)          # (out,)
        out = scores.shape[0]
        n_keep = max(1, int(round(keep_frac * out)))
        _, idx = jax.lax.top_k(scores, n_keep)
        kept = jax.nn.one_hot(idx, out, dtype=jnp.float32).sum(axis=0)
        return jnp.broadcast_to(kept, theta.shape)

    return jax.tree.map(mask_leaf, client_params, global_params)


def aggregate_fedadp(stacked_params: Pytree, global_params: Pytree,
                     data_sizes: jnp.ndarray, keep_frac: float) -> Pytree:
    """Element-wise masked aggregation over the client axis.

    stacked_params: leaves (K, ...). Falls back to the previous global value
    where no client uploaded an entry.
    """
    masks = jax.vmap(lambda p: neuron_masks(p, global_params, keep_frac))(
        stacked_params)
    w = data_sizes.astype(jnp.float32)

    def combine(theta, m, g):
        wx = w.reshape((-1,) + (1,) * (theta.ndim - 1))
        numer = jnp.sum(theta.astype(jnp.float32) * m * wx, axis=0)
        denom = jnp.sum(m * wx, axis=0)
        agg = jnp.where(denom > 0, numer / jnp.where(denom > 0, denom, 1.0),
                        g.astype(jnp.float32))
        return agg.astype(g.dtype)

    return jax.tree.map(combine, stacked_params, masks, global_params)


def fedadp_psum_parts(stacked_params: Pytree, global_params: Pytree,
                      data_sizes: jnp.ndarray,
                      keep_frac: float) -> tuple[Pytree, Pytree]:
    """Local halves of :func:`aggregate_fedadp` for the mesh engine's fused
    per-round psum: masked numerators ``Σ_k θ·m·w`` and element-wise
    denominators ``Σ_k m·w`` over this device's local client stack. Both
    are additive over the client axis, so psum-ing the per-device partials
    and dividing reproduces the single-device aggregation (up to fp32
    reduction order). The denominator is a param-structured tree — the
    engine shards it alongside the numerators on 2-D meshes."""
    masks = jax.vmap(lambda p: neuron_masks(p, global_params, keep_frac))(
        stacked_params)
    w = data_sizes.astype(jnp.float32)

    def wx_for(theta):
        return w.reshape((-1,) + (1,) * (theta.ndim - 1))

    numer = jax.tree.map(
        lambda theta, m: jnp.sum(theta.astype(jnp.float32) * m
                                 * wx_for(theta), axis=0),
        stacked_params, masks)
    denom = jax.tree.map(
        lambda theta, m: jnp.sum(m * wx_for(theta), axis=0),
        stacked_params, masks)
    return numer, denom


def fedadp_psum_finalize(numer: Pytree, denom: Pytree,
                         global_params: Pytree) -> Pytree:
    """Replicated epilogue: element-wise division with fallback to the
    previous global value where no client uploaded an entry. Element-wise,
    so it is shard-safe (runs on 1/M 'model'-axis slices unchanged)."""

    def combine(n, d, g):
        agg = jnp.where(d > 0, n / jnp.where(d > 0, d, 1.0),
                        g.astype(jnp.float32))
        return agg.astype(g.dtype)

    return jax.tree.map(combine, numer, denom, global_params)


def comm_bytes(global_params: Pytree, num_clients: int,
               keep_frac: float) -> float:
    """Modeled uplink bytes per round: kept neurons + per-neuron index
    overhead (4 B each, standard sparse-upload encoding)."""
    total = 0.0
    for leaf in jax.tree.leaves(global_params):
        out = leaf.shape[-1] if leaf.ndim >= 1 else 1
        n_keep = max(1, int(round(keep_frac * out)))
        per_neuron = leaf.size // out * leaf.dtype.itemsize
        total += n_keep * (per_neuron + 4)
    return num_clients * total
