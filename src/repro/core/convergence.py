"""Theorem 1 — convergence bound calculator.

Implements the closed-form bound on the FedLDF↔FedAvg loss gap:

    F(Ĝ^{t+1}) − F(Ḡ^{t+1}) ≤ A^t [F(Ĝ^0) − F(Ḡ^0)] + B·(1 − A^t)/(1 − A)

with  A = 2ξ₂η²L²(1 − n/K)[1 + β(1 − n/K)]
      B = (ξ₁/ξ₂)·A + (1 − n/K)·G²/2

and the convergence condition 0 < ξ₂ < 1 / (2(1+β)η²L²).

Used by `benchmarks/bound.py` to verify the paper's analytical claims
(gap shrinks as n→K; A<1 condition; asymptotic gap formula) and by tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class BoundParams:
    """Assumption constants (Assumptions 1-3) + protocol knobs."""

    beta: float          # smoothness
    xi1: float           # gradient-divergence intercept (Assumption 2)
    xi2: float           # gradient-divergence slope (Assumption 2)
    grad_bound: float    # G (Assumption 3)
    eta: float           # learning rate
    num_layers: int      # L
    n: int               # clients uploading each layer
    k: int               # participating clients


def contraction_A(p: BoundParams) -> float:
    """A = 2ξ₂η²L²(1−n/K)[1+β(1−n/K)]."""
    r = 1.0 - p.n / p.k
    return 2.0 * p.xi2 * p.eta**2 * p.num_layers**2 * r * (1.0 + p.beta * r)


def offset_B(p: BoundParams) -> float:
    """B = (ξ₁/ξ₂)A + (1−n/K)G²/2."""
    r = 1.0 - p.n / p.k
    return (p.xi1 / p.xi2) * contraction_A(p) + r * p.grad_bound**2 / 2.0


def xi2_max(p: BoundParams) -> float:
    """Convergence condition: ξ₂ < 1 / (2(1+β)η²L²)."""
    return 1.0 / (2.0 * (1.0 + p.beta) * p.eta**2 * p.num_layers**2)


def converges(p: BoundParams) -> bool:
    return 0.0 < p.xi2 < xi2_max(p) and contraction_A(p) < 1.0


def gap_bound(p: BoundParams, t: int, gap0: float) -> float:
    """Right-hand side of Eq. 9 after t rounds."""
    a = contraction_A(p)
    b = offset_B(p)
    if abs(1.0 - a) < 1e-12:
        return a**t * gap0 + b * t
    return a**t * gap0 + b * (1.0 - a**t) / (1.0 - a)


def asymptotic_gap(p: BoundParams) -> float:
    """t→∞ limit discussed under Theorem 1:
    ((1−n/K)G²/2 + ξ₁/ξ₂·A)/(1−A)  — equals B/(1−A); 0 when n = K."""
    a = contraction_A(p)
    if a >= 1.0:
        return np.inf
    return offset_B(p) / (1.0 - a)


def gap_curve(p: BoundParams, rounds: int, gap0: float = 0.0) -> np.ndarray:
    """Vectorised bound over t = 0..rounds (for benchmark plots/CSV)."""
    return np.array([gap_bound(p, t, gap0) for t in range(rounds + 1)])
