"""Trainable/frozen parameter partition — the adapter fine-tuning seam.

FedLDF's premise (Eq. 3-5) is that only the *divergent subset* of the model
needs to travel; a :class:`ParamPartition` makes that subset an explicit
engine-level contract. Every parameter leaf is classified

- **trainable** — receives local gradients, travels the wire, is scored by
  the Eq. 3 divergence, and is eligible for error feedback / quantization
  (the unit map, strategy state schemas, comm accounting, and the packed
  wire format are all built over this sub-pytree only); or
- **frozen** — the device-resident base model: broadcast once at round 0,
  closed over by local training, never uploaded, never psum'd.

``FLConfig(partition=None)`` (the default) is today's everything-trainable
behavior, bit-identically — the engines only split/merge when a partition
is present.

The partition itself is **static data**: leaf *paths* ("/"-joined dict
keys, e.g. ``"blocks/attn/lora/wq/a"``), not arrays. It is a frozen,
hashable dataclass so it can ride :class:`~repro.federated.server.FLConfig`
straight through the engine's compiled-callable cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

Pytree = Any


def leaf_paths(tree: Pytree, prefix: str = "") -> Iterator[tuple[str, Any]]:
    """Yield ("/"-joined path, leaf) pairs of a nested-dict pytree in
    sorted-key order (the same ordering :mod:`repro.launch.sharding` uses)."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from leaf_paths(tree[k], f"{prefix}{k}/")
    else:
        yield prefix.rstrip("/"), tree


def _assign(out: dict, path: str, leaf) -> None:
    keys = path.split("/")
    node = out
    for k in keys[:-1]:
        node = node.setdefault(k, {})
    node[keys[-1]] = leaf


@dataclasses.dataclass(frozen=True)
class ParamPartition:
    """Static trainable/frozen classification of a parameter pytree.

    Hold only leaf paths (hashable tuples) — never arrays — so an equal
    partition hashes equal and two runs differing only in partition
    *values* cannot alias a compiled round.
    """

    trainable_paths: tuple[str, ...]
    frozen_paths: tuple[str, ...]

    def __post_init__(self):
        overlap = set(self.trainable_paths) & set(self.frozen_paths)
        if overlap:
            raise ValueError(
                f"paths classified both trainable and frozen: "
                f"{sorted(overlap)[:4]}")
        if not self.trainable_paths:
            raise ValueError(
                "a ParamPartition needs at least one trainable leaf "
                "(an all-frozen model has nothing to train or upload)")

    # ------------------------------------------------------------------
    @staticmethod
    def build(params: Pytree,
              is_trainable: Callable[[str, Any], bool]) -> "ParamPartition":
        """Classify every leaf of ``params`` with ``is_trainable(path, leaf)``."""
        if not isinstance(params, dict):
            raise TypeError("ParamPartition.build expects a top-level dict "
                            "pytree (the engine param layout)")
        train, frozen = [], []
        for path, leaf in leaf_paths(params):
            (train if is_trainable(path, leaf) else frozen).append(path)
        return ParamPartition(tuple(train), tuple(frozen))

    @staticmethod
    def by_keys(params: Pytree,
                trainable_keys: tuple[str, ...] | list[str]
                ) -> "ParamPartition":
        """Partition on top-level keys: subtrees named in ``trainable_keys``
        are trainable, everything else frozen."""
        keys = set(trainable_keys)
        unknown = keys - set(params)
        if unknown:
            raise KeyError(f"trainable_keys not in params: {sorted(unknown)}")
        return ParamPartition.build(
            params, lambda path, _: path.split("/", 1)[0] in keys)

    @staticmethod
    def by_substring(params: Pytree, marker: str) -> "ParamPartition":
        """Leaves whose path contains ``marker`` (e.g. ``"lora"``) are
        trainable; the rest are the frozen base."""
        return ParamPartition.build(
            params, lambda path, _: marker in path.split("/"))

    # ------------------------------------------------------------------
    @property
    def all_trainable(self) -> bool:
        return not self.frozen_paths

    def _check(self, params: Pytree) -> None:
        have = [p for p, _ in leaf_paths(params)]
        want = set(self.trainable_paths) | set(self.frozen_paths)
        missing = want - set(have)
        extra = set(have) - want
        if missing or extra:
            raise ValueError(
                "params do not match this partition "
                f"(missing={sorted(missing)[:4]}, "
                f"unclassified={sorted(extra)[:4]}) — rebuild the "
                "partition against the model you are training")

    def split(self, params: Pytree) -> tuple[Pytree, Pytree]:
        """``params -> (trainable, frozen)`` complementary nested dicts.

        Validates that the partition's paths exactly cover ``params`` —
        a partition built against one model cannot silently misclassify
        another.
        """
        self._check(params)
        tset = set(self.trainable_paths)
        train: dict = {}
        frozen: dict = {}
        for path, leaf in leaf_paths(params):
            _assign(train if path in tset else frozen, path, leaf)
        return train, frozen

    def merge(self, trainable: Pytree, frozen: Pytree) -> Pytree:
        """Inverse of :meth:`split`: reassemble the full param pytree."""
        out: dict = {}
        for tree in (frozen, trainable):
            for path, leaf in leaf_paths(tree):
                _assign(out, path, leaf)
        return out


def partition_counts(partition: ParamPartition, params: Pytree) -> dict:
    """Static trainable/frozen param + byte totals (ledger metadata)."""
    import numpy as np
    tset = set(partition.trainable_paths)
    out = {"trainable_params": 0, "frozen_params": 0,
           "trainable_bytes": 0, "frozen_bytes": 0}
    for path, leaf in leaf_paths(params):
        kind = "trainable" if path in tset else "frozen"
        out[f"{kind}_params"] += int(np.prod(leaf.shape))
        out[f"{kind}_bytes"] += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return out
