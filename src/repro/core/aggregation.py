"""Model aggregation (paper Eqs. 1, 5, 6).

Two execution layouts are supported:

- **stacked** (``vmap`` client mode): all K client models are materialised
  with a leading client axis; aggregation is a masked weighted mean over that
  axis (small models — the paper's own VGG-9 regime).
- **streaming** (``scan`` client mode): clients are visited sequentially and
  added into a float32 accumulator with per-unit weights (large models; see
  DESIGN.md §3 two-phase recompute).

The stacked layout additionally supports a *client-sharded* reduction
(``aggregate_stacked(..., axis_name='clients')`` inside ``shard_map``): each
device pre-reduces its local clients, then numerators and denominators are
``psum``'d across the mesh so every device holds the same new global model.

Both produce bitwise-identical semantics: Eq. 5
``Ĝ_u = Σ_k s[k,u]·w_k·Θ_{k,u} / Σ_m s[m,u]·w_m``.

With ``s ≡ 1`` this is exactly FedAvg (Eq. 1) — tested as the n=K degeneracy
of Theorem 1.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.units import UnitMap, tree_zeros_like

Pytree = Any


def unit_weights(selection: jnp.ndarray,
                 data_sizes: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(client, unit) aggregation weights and per-unit denominators.

    selection: (K, U) ∈ {0,1}; data_sizes: (K,) |D_k|.
    Returns (numer_w: (K, U), denom: (U,)) with
    ``numer_w[k,u] = s[k,u]·|D_k|`` and ``denom[u] = Σ_m s[m,u]·|D_m|``.
    """
    w = selection * data_sizes[:, None].astype(jnp.float32)
    return w, w.sum(axis=0)


def aggregate_stacked(stacked_params: Pytree, umap: UnitMap,
                      selection: jnp.ndarray, data_sizes: jnp.ndarray,
                      fallback: Pytree | None = None,
                      axis_name: str | None = None) -> Pytree:
    """Eq. 5 over client-stacked params (every leaf has leading K).

    ``fallback`` (usually the previous global model) is used for any unit
    whose denominator is zero (cannot happen with top-n selection, which
    guarantees n ≥ 1 clients per unit, but can with dropout-style policies).

    ``axis_name`` turns this into the cross-device reduction of a
    client-sharded round (``shard_map`` over a ``'clients'`` mesh axis):
    inputs are then the *local* shard — ``selection``/``data_sizes`` rows and
    stacked leaves for this device's K/D clients. Each device pre-reduces
    its own clients *unnormalised* (Σ_k s·w_k·Θ_k), then the numerators of
    every leaf AND the Eq. 5 denominator travel in **one fused psum** (a
    pytree collective) — one cross-device rendezvous per round instead of
    one per parameter leaf, which is what makes the sharded round scale on
    oversubscribed CPU meshes as well as real accelerator fabrics. The
    division by Σ_m s·w_m happens after the collective, so the math matches
    the unsharded call up to fp32 summation/normalisation order — hence the
    sharded-vs-unsharded trajectory tests use a tight fp32 tolerance rather
    than bit equality.
    """
    if axis_name is not None:
        return _aggregate_stacked_psum(stacked_params, umap, selection,
                                       data_sizes, fallback, axis_name)
    w, denom = unit_weights(selection, data_sizes)          # (K,U), (U,)
    safe = jnp.where(denom > 0, denom, 1.0)
    frac = w / safe[None, :]                                # (K, U)

    k = selection.shape[0]

    def agg_one(key: str):
        off, n = umap.spans[key]
        seg = jax.lax.dynamic_slice(frac, (0, off), (k, n))  # (K, n)
        seg_d = jax.lax.dynamic_slice(denom, (off,), (n,))   # (n,)

        def combine(leaf, fb):
            # leaf: (K, n, ...) for stacked units, (K, ...) otherwise.
            if n > 1:
                wx = seg.reshape((k, n) + (1,) * (leaf.ndim - 2))
            else:
                wx = seg.reshape((k,) + (1,) * (leaf.ndim - 1))
            out = jnp.sum(leaf.astype(jnp.float32) * wx, axis=0)
            if fb is not None:
                if n > 1:
                    alive = (seg_d > 0).reshape((n,) + (1,) * (out.ndim - 1))
                else:
                    alive = seg_d[0] > 0
                out = jnp.where(alive, out, fb.astype(jnp.float32))
            return out.astype(leaf.dtype)

        fsub = fallback[key] if fallback is not None else None
        if fsub is None:
            return jax.tree.map(lambda l: combine(l, None),
                                stacked_params[key])
        return jax.tree.map(combine, stacked_params[key], fsub)

    return {key: agg_one(key) for key in stacked_params}


def stacked_psum_parts(stacked_params: Pytree, umap: UnitMap,
                       selection: jnp.ndarray, data_sizes: jnp.ndarray
                       ) -> tuple[Pytree, jnp.ndarray]:
    """Device-local half of the client-sharded Eq. 5: unnormalised
    numerators (Σ_k s·w_k·Θ_k per leaf, fp32) and the local denominator
    rows' contribution (U,). Both are *additive* across the mesh axis, so
    the caller can fold them — together with any other additive per-round
    stats (loss sums, comm bytes) — into one fused ``psum``, then call
    :func:`stacked_psum_finalize` on the reduced values. On a 2-D
    ('clients', 'model') mesh the caller may slice each numerator leaf down
    to its 'model'-axis shard *before* the psum (the reduction runs over
    'clients' only, per model column) — the unit-axis bookkeeping below
    never touches the sharded leaf dims, so parts/finalize work unchanged
    on 1/M slices."""
    w, denom_loc = unit_weights(selection, data_sizes)      # local (K,U),(U,)
    k = selection.shape[0]

    def partial_one(key: str):
        off, n = umap.spans[key]
        seg = jax.lax.dynamic_slice(w, (0, off), (k, n))     # (K, n)

        def num(leaf):
            if n > 1:
                wx = seg.reshape((k, n) + (1,) * (leaf.ndim - 2))
            else:
                wx = seg.reshape((k,) + (1,) * (leaf.ndim - 1))
            return jnp.sum(leaf.astype(jnp.float32) * wx, axis=0)

        return jax.tree.map(num, stacked_params[key])

    return ({key: partial_one(key) for key in stacked_params}, denom_loc)


def stacked_psum_finalize(partials: Pytree, denom: jnp.ndarray,
                          umap: UnitMap, stacked_params: Pytree,
                          fallback: Pytree | None) -> Pytree:
    """Replicated epilogue of the client-sharded Eq. 5: divide the psum'd
    numerators by the global denominator, fall back to the previous global
    model for dead units, and cast back to the parameter dtype.
    ``stacked_params`` is only consulted for leaf dtypes (its leaves need
    not carry the stacked client axis — the sharded round passes its local
    param shards, whose dtypes match, and whose leaves/fallback may be 1/M
    'model'-axis slices aligned with the sliced numerators)."""
    safe = jnp.where(denom > 0, denom, 1.0)

    def finalize_one(key: str):
        off, n = umap.spans[key]
        seg_d = jax.lax.dynamic_slice(denom, (off,), (n,))
        seg_s = jax.lax.dynamic_slice(safe, (off,), (n,))

        def fin(p, leaf, fb):
            if n > 1:
                out = p / seg_s.reshape((n,) + (1,) * (p.ndim - 1))
                alive = (seg_d > 0).reshape((n,) + (1,) * (p.ndim - 1))
            else:
                out = p / seg_s[0]
                alive = seg_d[0] > 0
            if fb is not None:
                out = jnp.where(alive, out, fb.astype(jnp.float32))
            return out.astype(leaf.dtype)

        fsub = fallback[key] if fallback is not None else None
        if fsub is None:
            return jax.tree.map(lambda p, leaf: fin(p, leaf, None),
                                partials[key], stacked_params[key])
        return jax.tree.map(fin, partials[key], stacked_params[key], fsub)

    return {key: finalize_one(key) for key in stacked_params}


def _aggregate_stacked_psum(stacked_params: Pytree, umap: UnitMap,
                            selection: jnp.ndarray, data_sizes: jnp.ndarray,
                            fallback: Pytree | None,
                            axis_name: str) -> Pytree:
    """Client-sharded Eq. 5 (see :func:`aggregate_stacked`): local
    unnormalised partial sums, one fused (numerators, denominator) psum,
    then the division/fallback epilogue replicated on every device."""
    partials, denom_loc = stacked_psum_parts(stacked_params, umap,
                                             selection, data_sizes)
    partials, denom = jax.lax.psum((partials, denom_loc), axis_name)
    return stacked_psum_finalize(partials, denom, umap, stacked_params,
                                 fallback)


def hierarchical_psum(tree: Pytree, axis_name: str, axis_size: int,
                      group_size: int) -> Pytree:
    """Two-tier all-reduce over a named mesh axis (population-scale rounds).

    Tier 1: ``psum`` restricted to groups of ``group_size`` consecutive
    axis positions (``axis_index_groups`` — XLA keeps the collective on
    intra-group links, e.g. intra-host NVLink/ICI when the mesh is built
    host-contiguous). Tier 2: a ring all-reduce across the groups via
    ``lax.ppermute`` rotations by ``group_size`` — each step every position
    receives the previous group's running partial and accumulates it, so
    after ``num_groups - 1`` rotations every device holds the global sum
    without any single root absorbing all ``D`` partials. Cross-group
    traffic per device is O(num_groups) payloads instead of the flat
    reduce's O(D) at the root — server/root bandwidth stops being the
    ceiling (RingFed, arXiv:2107.08873).

    ``group_size == axis_size`` (one group) degenerates to a flat psum;
    ``group_size == 1`` is a pure ring all-reduce over all devices. The
    result equals ``jax.lax.psum(tree, axis_name)`` up to fp32 summation
    order (the equivalence tests use the usual fp32 tolerance).
    """
    if axis_size % group_size:
        raise ValueError(
            f"hierarchical_psum: group_size={group_size} must divide the "
            f"axis size {axis_size}")
    num_groups = axis_size // group_size
    if num_groups <= 1:
        return jax.lax.psum(tree, axis_name)
    if group_size > 1:
        groups = [[g * group_size + i for i in range(group_size)]
                  for g in range(num_groups)]
        tree = jax.lax.psum(tree, axis_name, axis_index_groups=groups)
    perm = [(i, (i + group_size) % axis_size) for i in range(axis_size)]
    acc, rot = tree, tree
    for _ in range(num_groups - 1):
        rot = jax.tree.map(
            lambda x: jax.lax.ppermute(x, axis_name, perm), rot)
        acc = jax.tree.map(jnp.add, acc, rot)
    return acc


def fedavg_stacked(stacked_params: Pytree, data_sizes: jnp.ndarray) -> Pytree:
    """Eq. 1 — plain FedAvg over client-stacked params."""
    w = data_sizes.astype(jnp.float32)
    frac = w / w.sum()

    def combine(leaf):
        wx = frac.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(leaf.astype(jnp.float32) * wx, axis=0).astype(leaf.dtype)

    return jax.tree.map(combine, stacked_params)


# ----------------------------------------------------------------------
# Streaming layout (scan over clients) — same math, O(1)-client memory.
# ----------------------------------------------------------------------
def streaming_init(global_params: Pytree) -> Pytree:
    """Float32 accumulator for Eq. 5 numerators."""
    return tree_zeros_like(global_params, dtype=jnp.float32)


def streaming_add(acc: Pytree, client_params: Pytree, umap: UnitMap,
                  client_frac: jnp.ndarray) -> Pytree:
    """acc += client_frac[u] * Θ_k  (client_frac = w[k]/denom, shape (U,))."""
    return umap.accumulate(acc, client_params, client_frac)


def streaming_finalize(acc: Pytree, umap: UnitMap, denom: jnp.ndarray,
                       fallback: Pytree) -> Pytree:
    """Replace zero-denominator units with the previous global model and cast
    back to the parameter dtype."""
    alive = (denom > 0).astype(jnp.float32)
    kept = umap.scale_by_unit(acc, alive)
    fb = umap.scale_by_unit(fallback, 1.0 - alive)
    return jax.tree.map(lambda a, b, g: (a + b.astype(jnp.float32)).astype(g.dtype),
                        kept, fb, fallback)
