"""Beyond-paper: low-rank delta upload (FedPara-adjacent, cited as [3]).

Orthogonal to selection (Eq. 4) and quantization (core/compress.py): each
*selected* 2-D layer uploads a rank-r factorization of its delta,
``Δ ≈ U V^T`` (U: m×r, V: n×r), computed by subspace (power) iteration —
jit-safe, no SVD. Uplink for that layer drops from ``m·n`` to ``r·(m+n)``
floats. Non-matrix leaves (norms, biases) upload dense (they are tiny).

Like quantization, the residual ``Δ − U V^T`` can be carried as client
error feedback so the truncation bias averages out across rounds.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.units import tree_sub

Pytree = Any


def _lowrank_approx(delta: jnp.ndarray, rank: int, iters: int = 2,
                    key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Rank-r approximation of a 2-D matrix via subspace iteration.

    ``key`` seeds the starting subspace; ``None`` keeps the legacy fixed
    ``PRNGKey(0)`` start (bit-compatible with the pre-key behaviour, but
    correlated across leaves/rounds — callers that care thread a key).
    """
    m, n = delta.shape
    r = min(rank, m, n)
    d32 = delta.astype(jnp.float32)
    if key is None:
        key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (n, r), jnp.float32)
    for _ in range(iters):
        q, _ = jnp.linalg.qr(d32.T @ (d32 @ q))        # (n, r)
    u = d32 @ q                                        # (m, r)
    return (u @ q.T).astype(delta.dtype)


def lowrank_upload(local: Pytree, global_params: Pytree, rank: int,
                   residual: Optional[Pytree] = None,
                   min_dim: int = 32,
                   key: Optional[jax.Array] = None) -> tuple[Pytree, Pytree]:
    """Client-side: (Θ̂ as reconstructed by the server, new residual).

    2-D leaves with both dims ≥ min_dim are rank-truncated; others dense.
    Stacked 3-D+ leaves factorize per leading index (vmapped). ``key``
    decorrelates the power-iteration starts: each leaf folds in its flat
    index, each stacked slice gets its own split; ``None`` reproduces the
    legacy shared fixed start.
    """
    delta = tree_sub(local, global_params)
    if residual is not None:
        delta = jax.tree.map(lambda d, e: d + e.astype(d.dtype),
                             delta, residual)

    def approx(leaf, leaf_key):
        if leaf.ndim == 2 and min(leaf.shape) >= min_dim:
            return _lowrank_approx(leaf, rank, key=leaf_key)
        if leaf.ndim >= 3 and min(leaf.shape[-2:]) >= min_dim:
            flat = leaf.reshape((-1,) + leaf.shape[-2:])
            if leaf_key is None:
                out = jax.vmap(lambda x: _lowrank_approx(x, rank))(flat)
            else:
                ks = jax.random.split(leaf_key, flat.shape[0])
                out = jax.vmap(
                    lambda x, k: _lowrank_approx(x, rank, key=k))(flat, ks)
            return out.reshape(leaf.shape)
        return leaf  # dense upload

    flat, treedef = jax.tree.flatten(delta)
    recon = jax.tree.unflatten(treedef, [
        approx(leaf, None if key is None else jax.random.fold_in(key, i))
        for i, leaf in enumerate(flat)])
    new_residual = jax.tree.map(
        lambda d, r_: d.astype(jnp.float32) - r_.astype(jnp.float32),
        delta, recon)
    theta_hat = jax.tree.map(
        lambda g, r_: (g.astype(jnp.float32)
                       + r_.astype(jnp.float32)).astype(g.dtype),
        global_params, recon)
    return theta_hat, new_residual


def lowrank_bytes(global_params: Pytree, rank: int,
                  min_dim: int = 32) -> float:
    """Modeled uplink bytes for one full-model low-rank upload."""
    total = 0.0
    for leaf in jax.tree.leaves(global_params):
        if leaf.ndim == 2 and min(leaf.shape) >= min_dim:
            m, n = leaf.shape
            r = min(rank, m, n)
            total += r * (m + n) * 4
        elif leaf.ndim >= 3 and min(leaf.shape[-2:]) >= min_dim:
            lead = 1
            for d in leaf.shape[:-2]:
                lead *= d
            m, n = leaf.shape[-2:]
            r = min(rank, m, n)
            total += lead * r * (m + n) * 4
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
