"""Layer-unit abstraction for FedLDF.

The paper (Eq. 3) computes one divergence scalar per *layer*. For VGG-9 a
layer is a conv/FC module; for the transformer zoo a natural unit is a block
depth (parameters are stacked ``(L, ...)`` under ``lax.scan``), plus separate
units for embedding / final norm / LM head.

A :class:`UnitMap` assigns every parameter leaf to one or more units:

- a *plain* top-level subtree (e.g. ``params['embed']``) is one unit;
- a *stacked* top-level subtree (e.g. ``params['blocks']`` whose leaves all
  share a leading depth dim ``L``) contributes ``L`` units, one per depth.

All reductions below are pure JAX and jit-safe; static structure (names,
sizes) is computed from shapes at trace time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

# Top-level keys whose leaves carry a leading stacked-depth dimension.
DEFAULT_STACKED_KEYS = ("blocks", "enc_blocks", "dec_blocks", "experts")


def _is_stacked(key: str, stacked_keys: Sequence[str]) -> bool:
    return key in stacked_keys


@dataclasses.dataclass(frozen=True)
class UnitMap:
    """Static description of layer units for a parameter pytree."""

    # Ordered unit names, e.g. ["blocks/0", ..., "blocks/L-1", "embed", ...].
    names: tuple[str, ...]
    # top-level key -> (unit offset, n_units). n_units > 1 means stacked.
    spans: dict[str, tuple[int, int]]
    # bytes per unit (static, from shapes/dtypes).
    unit_bytes: tuple[int, ...]
    # parameter count per unit.
    unit_params: tuple[int, ...]

    @property
    def num_units(self) -> int:
        return len(self.names)

    @property
    def total_bytes(self) -> int:
        return int(sum(self.unit_bytes))

    @property
    def total_params(self) -> int:
        return int(sum(self.unit_params))

    # ------------------------------------------------------------------
    @staticmethod
    def build(params: Pytree,
              stacked_keys: Sequence[str] = DEFAULT_STACKED_KEYS) -> "UnitMap":
        if not isinstance(params, dict):
            raise TypeError("UnitMap.build expects a top-level dict pytree")
        names: list[str] = []
        spans: dict[str, tuple[int, int]] = {}
        nbytes: list[int] = []
        nparams: list[int] = []
        for key in sorted(params.keys()):
            sub = params[key]
            leaves = jax.tree.leaves(sub)
            if not leaves:
                continue
            if _is_stacked(key, stacked_keys):
                depth = leaves[0].shape[0]
                for leaf in leaves:
                    if leaf.ndim < 1 or leaf.shape[0] != depth:
                        raise ValueError(
                            f"stacked subtree {key!r} has inconsistent leading "
                            f"dims: {leaf.shape} vs depth {depth}")
                spans[key] = (len(names), depth)
                per_depth_bytes = sum(
                    int(np.prod(l.shape[1:])) * l.dtype.itemsize for l in leaves)
                per_depth_params = sum(
                    int(np.prod(l.shape[1:])) for l in leaves)
                for d in range(depth):
                    names.append(f"{key}/{d}")
                    nbytes.append(per_depth_bytes)
                    nparams.append(per_depth_params)
            else:
                spans[key] = (len(names), 1)
                names.append(key)
                nbytes.append(sum(int(np.prod(l.shape)) * l.dtype.itemsize
                                  for l in leaves))
                nparams.append(sum(int(np.prod(l.shape)) for l in leaves))
        return UnitMap(names=tuple(names), spans=spans,
                       unit_bytes=tuple(nbytes), unit_params=tuple(nparams))

    # ------------------------------------------------------------------
    def unit_bytes_array(self) -> jnp.ndarray:
        return jnp.asarray(self.unit_bytes, dtype=jnp.float32)

    # ------------------------------------------------------------------
    def sq_divergence(self, params: Pytree, ref: Pytree,
                      sqdiff_rowsum: Callable | None = None) -> jnp.ndarray:
        """Per-unit sum of squared differences, shape ``(U,)`` fp32.

        ``sqdiff_rowsum(a2d, b2d) -> (rows,)`` may be supplied to route the
        row-reduction through the Pallas kernel; defaults to pure jnp.
        """
        from repro.kernels import ops as kops  # local import; no cycle
        rowsum = sqdiff_rowsum or kops.sqdiff_rowsum
        out = jnp.zeros((self.num_units,), dtype=jnp.float32)
        for key, (off, n) in self.spans.items():
            a_leaves = jax.tree.leaves(params[key])
            b_leaves = jax.tree.leaves(ref[key])
            if n > 1:
                acc = jnp.zeros((n,), dtype=jnp.float32)
                for a, b in zip(a_leaves, b_leaves):
                    acc = acc + rowsum(a.reshape(n, -1), b.reshape(n, -1))
                out = jax.lax.dynamic_update_slice(out, acc, (off,))
            else:
                acc = jnp.zeros((1,), dtype=jnp.float32)
                for a, b in zip(a_leaves, b_leaves):
                    acc = acc + rowsum(a.reshape(1, -1), b.reshape(1, -1))
                out = jax.lax.dynamic_update_slice(out, acc, (off,))
        return out

    def divergence(self, params: Pytree, ref: Pytree,
                   sqdiff_rowsum: Callable | None = None) -> jnp.ndarray:
        """Eq. 3: per-unit L2 norm of (params − ref), shape ``(U,)``."""
        return jnp.sqrt(self.sq_divergence(params, ref, sqdiff_rowsum))

    # ------------------------------------------------------------------
    def scale_by_unit(self, tree: Pytree, per_unit: jnp.ndarray) -> Pytree:
        """Multiply each leaf by its unit's scalar (stacked: per-depth)."""
        out = {}
        for key in tree:
            off, n = self.spans[key]
            seg = jax.lax.dynamic_slice(per_unit, (off,), (n,))
            if n > 1:
                def mul(l, seg=seg):
                    return l * seg.astype(l.dtype).reshape((n,) + (1,) * (l.ndim - 1))
            else:
                def mul(l, seg=seg):
                    return l * seg[0].astype(l.dtype)
            out[key] = jax.tree.map(mul, tree[key])
        return out

    def accumulate(self, acc: Pytree, tree: Pytree, per_unit: jnp.ndarray,
                   masked_accumulate: Callable | None = None) -> Pytree:
        """``acc += per_unit[u(leaf)] * tree`` — the Eq. 5 inner accumulation.

        ``masked_accumulate(acc2d, x2d, w_rows) -> acc2d`` may route through
        the Pallas kernel; defaults to pure jnp.
        """
        from repro.kernels import ops as kops
        macc = masked_accumulate or kops.masked_accumulate
        out = {}
        for key in tree:
            off, n = self.spans[key]
            seg = jax.lax.dynamic_slice(per_unit, (off,), (n,))

            def upd(a, x, seg=seg, n=n):
                a2 = a.reshape(n, -1) if n > 1 else a.reshape(1, -1)
                x2 = x.reshape(n, -1) if n > 1 else x.reshape(1, -1)
                w = seg if n > 1 else seg[:1]
                return macc(a2, x2, w).reshape(a.shape)

            out[key] = jax.tree.map(upd, acc[key], tree[key])
        return out

    # ------------------------------------------------------------------
    def expand_to_leaves(self, tree: Pytree, per_unit: jnp.ndarray) -> Pytree:
        """Return a pytree matching ``tree`` whose leaves hold the unit value
        broadcast to the leaf shape (useful for elementwise algorithms)."""
        out = {}
        for key in tree:
            off, n = self.spans[key]
            seg = jax.lax.dynamic_slice(per_unit, (off,), (n,))
            if n > 1:
                def mk(l, seg=seg):
                    return jnp.broadcast_to(
                        seg.astype(l.dtype).reshape((n,) + (1,) * (l.ndim - 1)),
                        l.shape)
            else:
                def mk(l, seg=seg):
                    return jnp.broadcast_to(seg[0].astype(l.dtype), l.shape)
            out[key] = jax.tree.map(mk, tree[key])
        return out


# ----------------------------------------------------------------------
# Generic pytree helpers used across the framework.
# ----------------------------------------------------------------------
def tree_zeros_like(tree: Pytree, dtype=None) -> Pytree:
    return jax.tree.map(
        lambda l: jnp.zeros(l.shape, dtype or l.dtype), tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: Pytree, s) -> Pytree:
    return jax.tree.map(lambda l: l * jnp.asarray(s, dtype=l.dtype), tree)


def tree_axpy(a: Pytree, x: Pytree, alpha) -> Pytree:
    """a + alpha * x"""
    return jax.tree.map(
        lambda u, v: u + jnp.asarray(alpha, u.dtype) * v, a, x)


def tree_dot(a: Pytree, b: Pytree) -> jnp.ndarray:
    parts = jax.tree.map(
        lambda u, v: jnp.sum(u.astype(jnp.float32) * v.astype(jnp.float32)),
        a, b)
    return sum(jax.tree.leaves(parts), jnp.float32(0.0))


def tree_sq_norm(tree: Pytree) -> jnp.ndarray:
    return tree_dot(tree, tree)


def tree_bytes(tree: Pytree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


def tree_params(tree: Pytree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def tree_cast(tree: Pytree, dtype) -> Pytree:
    return jax.tree.map(lambda l: l.astype(dtype), tree)


def tree_stack_index(tree: Pytree, i) -> Pytree:
    """Index leading (client) axis of a stacked pytree."""
    return jax.tree.map(lambda l: l[i], tree)
