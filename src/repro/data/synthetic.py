"""Synthetic datasets (offline container — no CIFAR-10 download).

- ``make_image_dataset``: class-conditional structured images matching
  CIFAR-10's shape/stats (32×32×3, 10 classes). Each class has a smooth
  random prototype (low-frequency mixture) plus per-sample noise and a random
  shift, so a small CNN must actually learn class structure — accuracy-vs-
  communication orderings transfer qualitatively.
- ``make_lm_dataset``: per-domain Markov-chain token streams for LM-style FL
  (domains create natural non-IID client splits).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ArrayDataset:
    xs: np.ndarray
    ys: np.ndarray

    def __len__(self) -> int:
        return len(self.xs)


def _class_prototypes(rng: np.random.Generator, num_classes: int,
                      size: int, channels: int) -> np.ndarray:
    """Smooth low-frequency prototypes, unit variance."""
    freqs = rng.normal(size=(num_classes, 4, 2))
    phases = rng.uniform(0, 2 * np.pi, size=(num_classes, 4, channels))
    amps = rng.normal(size=(num_classes, 4, channels))
    yy, xx = np.meshgrid(np.linspace(0, 2 * np.pi, size),
                         np.linspace(0, 2 * np.pi, size), indexing="ij")
    protos = np.zeros((num_classes, size, size, channels), np.float32)
    for c in range(num_classes):
        for k in range(4):
            arg = freqs[c, k, 0] * yy + freqs[c, k, 1] * xx
            for ch in range(channels):
                protos[c, :, :, ch] += (amps[c, k, ch]
                                        * np.sin(arg + phases[c, k, ch]))
    protos /= protos.std(axis=(1, 2, 3), keepdims=True) + 1e-8
    return protos


def make_image_dataset(num_train: int = 50_000, num_test: int = 10_000,
                       num_classes: int = 10, size: int = 32,
                       channels: int = 3, noise: float = 0.8,
                       seed: int = 0) -> tuple[ArrayDataset, ArrayDataset]:
    rng = np.random.default_rng(seed)
    protos = _class_prototypes(rng, num_classes, size, channels)

    def gen(n):
        ys = rng.integers(0, num_classes, size=n)
        xs = protos[ys].copy()
        # random cyclic shift (weak augmentation-like variability)
        shifts = rng.integers(-4, 5, size=(n, 2))
        for i in range(n):
            xs[i] = np.roll(xs[i], shifts[i], axis=(0, 1))
        xs += noise * rng.normal(size=xs.shape).astype(np.float32)
        return ArrayDataset(xs.astype(np.float32), ys.astype(np.int32))

    return gen(num_train), gen(num_test)


def make_lm_dataset(num_sequences: int = 2048, seq_len: int = 128,
                    vocab: int = 512, num_domains: int = 8,
                    seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Markov-chain tokens. Returns (tokens (N, S), domain_ids (N,))."""
    rng = np.random.default_rng(seed)
    seqs = np.zeros((num_sequences, seq_len), np.int32)
    domains = rng.integers(0, num_domains, size=num_sequences)
    # sparse per-domain transition tables
    nexts = rng.integers(0, vocab, size=(num_domains, vocab, 4))
    for i in range(num_sequences):
        d = domains[i]
        tok = rng.integers(0, vocab)
        for t in range(seq_len):
            seqs[i, t] = tok
            if rng.random() < 0.1:            # occasional resample
                tok = rng.integers(0, vocab)
            else:
                tok = nexts[d, tok, rng.integers(0, 4)]
    return seqs, domains.astype(np.int32)
