"""Data pipeline: synthetic datasets + federated partitioning + batching
(host ``FederatedData`` and device-resident ``ClientShards``)."""
from repro.data.device import ClientShards
from repro.data.loader import FederatedData, lm_federated
from repro.data.partition import dirichlet_partition, iid_partition, partition_sizes
from repro.data.synthetic import ArrayDataset, make_image_dataset, make_lm_dataset

__all__ = ["ClientShards", "FederatedData", "lm_federated",
           "dirichlet_partition", "iid_partition", "partition_sizes",
           "ArrayDataset", "make_image_dataset", "make_lm_dataset"]
