"""Data pipeline: synthetic datasets + federated partitioning + batching."""
from repro.data.loader import FederatedData, lm_federated
from repro.data.partition import dirichlet_partition, iid_partition, partition_sizes
from repro.data.synthetic import ArrayDataset, make_image_dataset, make_lm_dataset

__all__ = ["FederatedData", "lm_federated", "dirichlet_partition",
           "iid_partition", "partition_sizes", "ArrayDataset",
           "make_image_dataset", "make_lm_dataset"]
