"""Batching for federated simulation.

``FederatedData`` owns the global arrays plus per-client index partitions and
serves stacked per-round batches: for a participant set ``C_t`` of K clients
it returns leaves shaped ``(K, batch, ...)`` ready for ``vmap`` (parallel
clients) or ``lax.scan`` (sequential clients) — see federated/server.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.partition import partition_sizes


@dataclasses.dataclass
class FederatedData:
    xs: np.ndarray                    # (N, ...) features (images or tokens)
    ys: np.ndarray                    # (N, ...) labels
    parts: list[np.ndarray]           # per-client index sets
    x_key: str = "images"
    y_key: str = "labels"

    @property
    def num_clients(self) -> int:
        return len(self.parts)

    def data_sizes(self) -> np.ndarray:
        return partition_sizes(self.parts)

    def client_batch(self, client: int, batch: int,
                     rng: np.random.Generator) -> dict:
        idx = self.parts[client]
        pick = rng.choice(idx, size=batch, replace=len(idx) < batch)
        return {self.x_key: self.xs[pick], self.y_key: self.ys[pick]}

    def round_batch(self, clients: np.ndarray, batch: int,
                    rng: np.random.Generator) -> dict:
        """Stacked (K, batch, ...) batch for the participant set."""
        parts = [self.client_batch(int(c), batch, rng) for c in clients]
        return {
            self.x_key: np.stack([p[self.x_key] for p in parts]),
            self.y_key: np.stack([p[self.y_key] for p in parts]),
        }


def lm_federated(tokens: np.ndarray, domains: np.ndarray,
                 num_clients: int, by_domain: bool = True,
                 seed: int = 0) -> FederatedData:
    """Wrap an LM token set as federated data (clients = domains: non-IID)."""
    rng = np.random.default_rng(seed)
    if by_domain:
        order = np.argsort(domains, kind="stable")
        parts = [np.sort(p) for p in np.array_split(order, num_clients)]
    else:
        parts = [np.sort(p) for p in
                 np.array_split(rng.permutation(len(tokens)), num_clients)]
    inputs = tokens[:, :-1]
    labels = tokens[:, 1:]
    return FederatedData(xs=inputs, ys=labels, parts=parts,
                         x_key="tokens", y_key="labels")
