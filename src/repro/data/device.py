"""Device-resident client shards for the multi-round scan engine.

The host driver re-gathers every round batch with numpy fancy indexing and
re-uploads it to the device (one host→device transfer per round). For the
``lax.scan``-over-rounds engine the whole dataset must live on device so a
round batch is a pure gather:

1. global arrays ``xs``/``ys`` are uploaded once;
2. per-client index partitions are padded into a dense ``(N, S)`` int32
   matrix (``S`` = largest client shard; padding repeats the client's own
   indices cyclically, and sampling never reads past ``part_sizes[c]``);
3. a round batch for participants ``clients`` is two device gathers:
   a local index draw ``j ~ U[0, |D_c|)`` per (client, sample) followed by
   ``xs[part_idx[clients, j]]``.

Population scale adds a second layout, **sample-axis sharding with pinned
client→device affinity** (:meth:`ClientShards.with_affinity` /
``place(mesh, shard_samples=True)``): samples are permuted into contiguous
per-device blocks keyed by a static client→group assignment (group ``g``
owns clients ``[g·N/G, (g+1)·N/G)``), ``xs``/``ys`` are sharded
``P('clients')`` along the sample axis — at-rest dataset bytes/device drop
~1/D — and :meth:`gather` switches to a device-local index path inside
``shard_map`` so the round-batch gather never crosses devices. The cohort
must then be drawn per affinity group
(:func:`repro.federated.sampling.sample_clients_grouped`) so device ``g``'s
positional K/D participant rows are exactly clients whose data lives on it.

``ClientShards`` is registered as a pytree so it can be passed through
``jax.jit`` boundaries without baking the dataset into the jaxpr as a
constant. The affinity metadata (``group_block``, ``num_groups``) is static
aux data — engines branch on it at trace time.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.loader import FederatedData


@dataclasses.dataclass(frozen=True)
class ClientShards:
    xs: jnp.ndarray          # (total, ...) features, device-resident
    ys: jnp.ndarray          # (total, ...) labels, device-resident
    part_idx: jnp.ndarray    # (N, S) padded global indices, int32
    part_sizes: jnp.ndarray  # (N,) true shard sizes, int32
    x_key: str = "images"
    y_key: str = "labels"
    # affinity layout metadata (static): samples re-ordered into
    # ``num_groups`` contiguous blocks of ``group_block`` rows, block g
    # holding exactly the samples of clients [g·N/G, (g+1)·N/G).
    # group_block == 0 means no affinity layout (the original order).
    group_block: int = 0
    num_groups: int = 1

    @property
    def num_clients(self) -> int:
        return self.part_idx.shape[0]

    def data_sizes(self) -> jnp.ndarray:
        """|D_k| vector (float32) for the Eq. 5 weighting."""
        return self.part_sizes.astype(jnp.float32)

    def bytes_per_device(self) -> int:
        """At-rest dataset bytes held by ONE device (xs + ys).

        Replicated placement: the full arrays. Sample-sharded placement
        (``place(mesh, shard_samples=True)``): one 1/D block — the ~1/D
        shrink the population benchmark asserts.
        """
        total = 0
        for arr in (self.xs, self.ys):
            shards = getattr(arr, "addressable_shards", None)
            total += (shards[0].data.nbytes if shards
                      else np.asarray(arr).nbytes)
        return int(total)

    # ------------------------------------------------------------------
    @staticmethod
    def from_federated(fldata: FederatedData,
                       max_shard_cap: int | None = None) -> "ClientShards":
        """Build device shards from a host partition (vectorized).

        The padded index matrix is assembled with one numpy gather instead
        of a Python loop over N clients (the loop was O(N·S) host time —
        minutes at N=1e6). Identical output: row ``c`` is
        ``parts[c][m % |D_c|]`` for every column ``m``, i.e. the real
        indices followed by the same cyclic padding as before.

        ``max_shard_cap`` bounds the padded width S (and memory: the dense
        matrix is N×S int32, sized by the single largest shard without a
        cap). Clients larger than the cap keep only their first
        ``max_shard_cap`` sample indices and report the capped size in
        ``part_sizes`` — so sampling and the Eq. 5 |D_k| weights both see
        the truncated shard (documented trade-off for long-tailed
        partitions at population scale).
        """
        parts = fldata.parts
        n = len(parts)
        sizes = np.fromiter((len(p) for p in parts), dtype=np.int64,
                            count=n)
        smax = int(sizes.max())
        if max_shard_cap is not None:
            if max_shard_cap < 1:
                raise ValueError(f"max_shard_cap must be >= 1, got "
                                 f"{max_shard_cap}")
            smax = min(smax, int(max_shard_cap))
        eff = np.minimum(sizes, smax)
        flat = np.concatenate([np.asarray(p) for p in parts])
        starts = np.concatenate(([0], np.cumsum(sizes)[:-1]))
        cols = np.arange(smax, dtype=np.int64)[None, :]
        # row c, col m -> parts[c][m % eff[c]]  (cyclic pad, every slot a
        # valid sample; zero-size shards never occur via partitioners but
        # are guarded so the modulo stays defined)
        take = starts[:, None] + cols % np.maximum(eff, 1)[:, None]
        idx = flat[take].astype(np.int32)
        return ClientShards(
            xs=jnp.asarray(fldata.xs), ys=jnp.asarray(fldata.ys),
            part_idx=jnp.asarray(idx),
            part_sizes=jnp.asarray(eff.astype(np.int32)),
            x_key=fldata.x_key, y_key=fldata.y_key)

    # ------------------------------------------------------------------
    def with_affinity(self, num_groups: int) -> "ClientShards":
        """Re-layout samples into contiguous per-group blocks (host-side).

        Group ``g`` owns clients ``[g·N/G, (g+1)·N/G)``; its block holds
        those clients' samples back to back, padded to the largest group's
        sample total ``B`` so the sample axis splits evenly over a
        ``'clients'`` mesh axis (padding rows are copies of row 0, never
        addressed — ``part_idx`` only references real sample positions).
        ``part_idx`` is rewritten into the new coordinates with the same
        cyclic-pad contract, so :meth:`gather` returns identical batch
        VALUES for any ``(clients, key)`` — the re-layout is pure data
        movement. Idempotent for a matching ``num_groups``.
        """
        n = self.num_clients
        if num_groups <= 1:
            return self
        if self.num_groups == num_groups and self.group_block:
            return self
        if n % num_groups:
            raise ValueError(
                f"with_affinity: num_clients={n} must divide into "
                f"{num_groups} groups")
        xs = np.asarray(self.xs)
        ys = np.asarray(self.ys)
        part_idx = np.asarray(self.part_idx)
        sizes = np.asarray(self.part_sizes).astype(np.int64)
        cpg = n // num_groups
        group_sizes = sizes.reshape(num_groups, cpg).sum(axis=1)
        blk = int(group_sizes.max())
        # destination of each client's first sample: group base + the
        # within-group exclusive cumulative sum of shard sizes
        csum = np.cumsum(sizes) - sizes
        gstart = csum.reshape(num_groups, cpg)[:, 0]
        dest0 = (np.repeat(np.arange(num_groups, dtype=np.int64) * blk, cpg)
                 + (csum - np.repeat(gstart, cpg)))
        smax = part_idx.shape[1]
        cols = np.arange(smax, dtype=np.int64)[None, :]
        valid = cols < sizes[:, None]
        dest = dest0[:, None] + cols
        order = np.zeros(num_groups * blk, dtype=np.int64)
        order[dest[valid]] = part_idx[valid]
        new_idx = (dest0[:, None]
                   + cols % np.maximum(sizes, 1)[:, None]).astype(np.int32)
        return ClientShards(
            xs=jnp.asarray(xs[order]), ys=jnp.asarray(ys[order]),
            part_idx=jnp.asarray(new_idx), part_sizes=self.part_sizes,
            x_key=self.x_key, y_key=self.y_key,
            group_block=blk, num_groups=num_groups)

    # ------------------------------------------------------------------
    def place(self, mesh, shard_samples: bool = False) -> "ClientShards":
        """Place the dataset over a device mesh (sharded engine).

        ``shard_samples=False`` (default): the global arrays are
        *replicated* (PartitionSpec()) — any device may need any sample,
        because the per-round participant set is a random subset of all N
        clients. With a local replica everywhere, the round-batch gather
        partitions cleanly over the 'clients' axis with no cross-device
        traffic, but every device pays the full dataset's memory.

        ``shard_samples=True``: the sample axis is SHARDED 1/D along
        'clients' — :meth:`with_affinity` first permutes samples into
        contiguous per-device blocks keyed by the static client→device
        assignment (applied on the fly here if not already laid out), then
        ``xs``/``ys`` are placed ``P('clients')`` on axis 0 while the
        (small) index matrices stay replicated. At-rest dataset
        bytes/device drop ~1/D; :meth:`gather` reads only device-local
        rows when the participant cohort is drawn per affinity group
        (:func:`repro.federated.sampling.sample_clients_grouped` — the
        drivers switch automatically on ``num_groups > 1``). On a 2-D
        ('clients', 'model') mesh the samples stay replicated along
        'model' (only params and the EF residual store are model-sharded).
        """
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        src = self
        put = {"xs": rep, "ys": rep}
        if shard_samples:
            from repro.launch.mesh import CLIENT_AXIS, client_mesh_size
            d = client_mesh_size(mesh)
            if d > 1:
                src = self.with_affinity(d)
                row = NamedSharding(mesh, PartitionSpec(CLIENT_AXIS))
                put = {"xs": row, "ys": row}
        return ClientShards(
            xs=jax.device_put(src.xs, put["xs"]),
            ys=jax.device_put(src.ys, put["ys"]),
            part_idx=jax.device_put(src.part_idx, rep),
            part_sizes=jax.device_put(src.part_sizes, rep),
            x_key=src.x_key, y_key=src.y_key,
            group_block=src.group_block, num_groups=src.num_groups)

    # ------------------------------------------------------------------
    def gather(self, clients: jnp.ndarray, batch: int,
               key: jax.Array, mesh=None) -> dict:
        """Stacked (K, batch, ...) round batch, fully on device.

        Samples uniformly **with replacement** over each client's shard
        (a fixed-shape device draw; the numpy host path instead draws
        without replacement whenever the shard is at least batch-sized, so
        the two samplers differ in batch semantics, not just RNG stream).
        Determinism comes from ``key`` alone, so the host driver with
        ``sampler="jax"`` gathers bit-identical batches to the scan engine.

        ``mesh``: when gathering inside a jitted multi-device program, pass
        the engine's mesh so the random index draw runs replicated inside a
        ``shard_map`` (:func:`repro.launch.mesh.replicated_rng`). Under the
        default non-partitionable threefry, XLA is otherwise free to shard
        the random op's lowering across devices, which silently changes
        (and biases) the drawn values. The (pure, integer) gathers
        downstream may be partitioned freely — partitioning cannot change
        their values.

        With an affinity layout matching the mesh's 'clients' size and a
        per-group participant cohort, the sample gather itself runs
        device-LOCAL: a ``shard_map`` splits ``xs``/``ys`` and the drawn
        global indices over 'clients', each device rebases its rows by its
        ``axis_index · group_block`` offset and takes from its local block
        only — no cross-device traffic even when the dataset is
        sample-sharded. Values are identical to the global take (the
        rebased index addresses the same sample).
        """
        k = clients.shape[0]
        sizes = self.part_sizes[clients]                        # (K,)

        def draw(key_, sizes_):
            return jax.random.randint(key_, (k, batch), 0, sizes_[:, None])

        if mesh is not None:
            from repro.launch.mesh import replicated_rng
            j = replicated_rng(draw, mesh)(key, sizes)
        else:
            j = draw(key, sizes)
        gidx = self.part_idx[clients[:, None], j]               # (K, batch)

        if mesh is not None and self.group_block and self.num_groups > 1:
            from repro.launch.mesh import (CLIENT_AXIS, client_mesh_size,
                                           shard_map_norep)
            if (self.num_groups == client_mesh_size(mesh)
                    and k % self.num_groups == 0):
                from jax.sharding import PartitionSpec as P
                blk = self.group_block

                def local_take(xs_loc, ys_loc, gidx_loc):
                    g = jax.lax.axis_index(CLIENT_AXIS)
                    loc = gidx_loc - g * blk
                    return (jnp.take(xs_loc, loc, axis=0),
                            jnp.take(ys_loc, loc, axis=0))

                xb, yb = shard_map_norep(
                    local_take, mesh,
                    in_specs=(P(CLIENT_AXIS), P(CLIENT_AXIS),
                              P(CLIENT_AXIS)),
                    out_specs=(P(CLIENT_AXIS), P(CLIENT_AXIS)))(
                        self.xs, self.ys, gidx)
                return {self.x_key: xb, self.y_key: yb}

        return {self.x_key: jnp.take(self.xs, gidx, axis=0),
                self.y_key: jnp.take(self.ys, gidx, axis=0)}


def _shards_flatten(s: ClientShards):
    return ((s.xs, s.ys, s.part_idx, s.part_sizes),
            (s.x_key, s.y_key, s.group_block, s.num_groups))


def _shards_unflatten(aux, children):
    xs, ys, part_idx, part_sizes = children
    return ClientShards(xs=xs, ys=ys, part_idx=part_idx,
                        part_sizes=part_sizes, x_key=aux[0], y_key=aux[1],
                        group_block=aux[2], num_groups=aux[3])


jax.tree_util.register_pytree_node(ClientShards, _shards_flatten,
                                   _shards_unflatten)
