"""Device-resident client shards for the multi-round scan engine.

The host driver re-gathers every round batch with numpy fancy indexing and
re-uploads it to the device (one host→device transfer per round). For the
``lax.scan``-over-rounds engine the whole dataset must live on device so a
round batch is a pure gather:

1. global arrays ``xs``/``ys`` are uploaded once;
2. per-client index partitions are padded into a dense ``(N, S)`` int32
   matrix (``S`` = largest client shard; padding repeats the client's own
   indices cyclically, and sampling never reads past ``part_sizes[c]``);
3. a round batch for participants ``clients`` is two device gathers:
   a local index draw ``j ~ U[0, |D_c|)`` per (client, sample) followed by
   ``xs[part_idx[clients, j]]``.

``ClientShards`` is registered as a pytree so it can be passed through
``jax.jit`` boundaries without baking the dataset into the jaxpr as a
constant.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.loader import FederatedData


@dataclasses.dataclass(frozen=True)
class ClientShards:
    xs: jnp.ndarray          # (total, ...) features, device-resident
    ys: jnp.ndarray          # (total, ...) labels, device-resident
    part_idx: jnp.ndarray    # (N, S) padded global indices, int32
    part_sizes: jnp.ndarray  # (N,) true shard sizes, int32
    x_key: str = "images"
    y_key: str = "labels"

    @property
    def num_clients(self) -> int:
        return self.part_idx.shape[0]

    def data_sizes(self) -> jnp.ndarray:
        """|D_k| vector (float32) for the Eq. 5 weighting."""
        return self.part_sizes.astype(jnp.float32)

    # ------------------------------------------------------------------
    @staticmethod
    def from_federated(fldata: FederatedData) -> "ClientShards":
        smax = max(len(p) for p in fldata.parts)
        n = len(fldata.parts)
        idx = np.zeros((n, smax), dtype=np.int32)
        for i, p in enumerate(fldata.parts):
            idx[i, :len(p)] = p
            if len(p) < smax:  # cyclic pad — every slot is a valid sample
                idx[i, len(p):] = p[np.arange(smax - len(p)) % len(p)]
        return ClientShards(
            xs=jnp.asarray(fldata.xs), ys=jnp.asarray(fldata.ys),
            part_idx=jnp.asarray(idx),
            part_sizes=jnp.asarray([len(p) for p in fldata.parts],
                                   dtype=jnp.int32),
            x_key=fldata.x_key, y_key=fldata.y_key)

    # ------------------------------------------------------------------
    def place(self, mesh) -> "ClientShards":
        """Replicate the dataset over a device mesh (sharded engine).

        The global arrays are *replicated* (PartitionSpec()) rather than
        sharded: any device may need any sample, because the per-round
        participant set is a random subset of all N clients. With a local
        replica everywhere, the round-batch gather partitions cleanly over
        the 'clients' axis — each device reads only its own K/D clients'
        rows and no cross-device traffic happens during data loading. On a
        2-D ('clients', 'model') mesh the dataset stays replicated along
        'model' too (only params and the EF residual store are
        model-sharded; sharding the *sample* axis is the follow-on tracked
        in ROADMAP.md).
        """
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        return ClientShards(
            xs=jax.device_put(self.xs, rep), ys=jax.device_put(self.ys, rep),
            part_idx=jax.device_put(self.part_idx, rep),
            part_sizes=jax.device_put(self.part_sizes, rep),
            x_key=self.x_key, y_key=self.y_key)

    # ------------------------------------------------------------------
    def gather(self, clients: jnp.ndarray, batch: int,
               key: jax.Array, mesh=None) -> dict:
        """Stacked (K, batch, ...) round batch, fully on device.

        Samples uniformly **with replacement** over each client's shard
        (a fixed-shape device draw; the numpy host path instead draws
        without replacement whenever the shard is at least batch-sized, so
        the two samplers differ in batch semantics, not just RNG stream).
        Determinism comes from ``key`` alone, so the host driver with
        ``sampler="jax"`` gathers bit-identical batches to the scan engine.

        ``mesh``: when gathering inside a jitted multi-device program, pass
        the engine's mesh so the random index draw runs replicated inside a
        ``shard_map`` (:func:`repro.launch.mesh.replicated_rng`). Under the
        default non-partitionable threefry, XLA is otherwise free to shard
        the random op's lowering across devices, which silently changes
        (and biases) the drawn values. The (pure, integer) gathers
        downstream may be partitioned freely — partitioning cannot change
        their values.
        """
        k = clients.shape[0]
        sizes = self.part_sizes[clients]                        # (K,)

        def draw(key_, sizes_):
            return jax.random.randint(key_, (k, batch), 0, sizes_[:, None])

        if mesh is not None:
            from repro.launch.mesh import replicated_rng
            j = replicated_rng(draw, mesh)(key, sizes)
        else:
            j = draw(key, sizes)
        gidx = self.part_idx[clients[:, None], j]               # (K, batch)
        return {self.x_key: jnp.take(self.xs, gidx, axis=0),
                self.y_key: jnp.take(self.ys, gidx, axis=0)}


def _shards_flatten(s: ClientShards):
    return ((s.xs, s.ys, s.part_idx, s.part_sizes), (s.x_key, s.y_key))


def _shards_unflatten(aux, children):
    xs, ys, part_idx, part_sizes = children
    return ClientShards(xs=xs, ys=ys, part_idx=part_idx,
                        part_sizes=part_sizes, x_key=aux[0], y_key=aux[1])


jax.tree_util.register_pytree_node(ClientShards, _shards_flatten,
                                   _shards_unflatten)
