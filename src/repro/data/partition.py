"""Client data partitioning (paper §III-A).

- IID: uniform random split, equal sizes (paper: 1 000 samples/client).
- Non-IID: Dirichlet(α) label-skew with α = 1 by default — different class
  mixtures AND different dataset sizes per client, as in the paper.
"""
from __future__ import annotations

import numpy as np


def iid_partition(labels: np.ndarray, num_clients: int,
                  seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels))
    return [np.sort(part) for part in np.array_split(idx, num_clients)]


def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        alpha: float = 1.0, seed: int = 0,
                        min_size: int = 8) -> list[np.ndarray]:
    """Label-skew Dirichlet split: for each class, proportions over clients
    ~ Dir(α). Re-samples until every client has ≥ min_size samples."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    for _ in range(100):
        parts: list[list[int]] = [[] for _ in range(num_clients)]
        for c in classes:
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet(alpha * np.ones(num_clients))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for cid, chunk in enumerate(np.split(idx_c, cuts)):
                parts[cid].extend(chunk.tolist())
        sizes = np.array([len(p) for p in parts])
        if sizes.min() >= min_size:
            return [np.sort(np.array(p, dtype=np.int64)) for p in parts]
    raise RuntimeError("dirichlet_partition: could not satisfy min_size")


def partition_sizes(parts: list[np.ndarray]) -> np.ndarray:
    """|D_k| vector used in the Eq. 5 weighting."""
    return np.array([len(p) for p in parts], dtype=np.float32)
