"""FedLDF reproduction: communication-efficient FL aggregation with layer
divergence feedback (Wang et al., 2024) as a multi-pod JAX framework."""
__version__ = "1.0.0"
