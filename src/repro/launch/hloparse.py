"""Loop-aware roofline extraction from compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scan-based program (layers, FL clients, flash-attention KV chunks) is
undercounted by the loop trip count — verified experimentally in this repo.
This parser recovers honest per-device totals:

1. split the HLO module into computations;
2. recover each while loop's trip count from the integer constant in its
   condition computation (scans lower to ``lt(counter, N)``);
3. weight every computation by the product of trip counts on the call path;
4. accumulate, per weighted instruction:
   - FLOPs: ``dot`` (2 · result_elems · contracted_elems) and
     ``convolution`` (2 · result_elems · window · in_features/group);
   - HBM bytes: operand + result bytes of top-level (post-fusion)
     instructions — fusion internals stay in registers/VMEM, so this is the
     natural roofline HBM-traffic model;
   - collective bytes: result-shape bytes of all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute.

The parser is validated in tests against unrolled-vs-scanned programs.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

def cost_analysis_dict(cost) -> dict:
    """Normalise ``compiled.cost_analysis()`` across JAX versions.

    Older JAX returns one dict per device (a list); current JAX returns the
    dict directly (or ``None`` on backends without cost analysis). Always
    returns a plain dict so callers can ``.get("flops", 0.0)``.
    """
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return dict(cost) if cost else {}


# one shape token: f32[1,2,3] (layout braces optional)
_SHAPE_TOK = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPND = re.compile(r"%([\w.\-]+)")


def _shape_bytes_elems(dtype: str, dims: str) -> tuple[int, int]:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4), n


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_bytes: int
    result_elems: int
    shapes: list            # [(dtype, [dims])] of the result(s)
    text: str
    operands: list


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_fusion_body: bool = False


ENTRY_KEY = "__entry__"


def parse_module(hlo: str) -> dict[str, Computation]:
    """Parse computations; the ENTRY computation name is stored under the
    ``ENTRY_KEY`` sentinel (as a string) for ``computation_weights``."""
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    fused_names: set[str] = set()
    entry_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry_name = cur.name
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # op name: first identifier after the result shape spec
        opm = re.search(r"\)?\s*([a-z][a-z0-9\-]*)\(", rhs)
        op = opm.group(1) if opm else ""
        head = rhs.split(op + "(", 1)[0] if op else rhs
        rbytes = relems = 0
        shapes = []
        for dtype, dims in _SHAPE_TOK.findall(head):
            if dtype in _DTYPE_BYTES:
                b, e = _shape_bytes_elems(dtype, dims)
                rbytes += b
                relems += e
                shapes.append((dtype, [int(d) for d in dims.split(",") if d]))
        body = rhs[len(head):]
        operands = _OPND.findall(body.split("),", 1)[0]) if op else []
        cur.instrs.append(Instr(name, op, rbytes, relems, shapes, rhs,
                                operands))
        if op == "fusion":
            fm = re.search(r"calls=%?([\w.\-]+)", rhs)
            if fm:
                fused_names.add(fm.group(1))
    for fname in fused_names:
        if fname in comps:
            comps[fname].is_fusion_body = True
    if entry_name is not None:
        comps[ENTRY_KEY] = entry_name  # type: ignore[assignment]
    return comps


def _trip_count_from_cond(cond: Computation) -> int:
    """Largest scalar int constant in the loop condition (counter bound)."""
    best = 1
    for ins in cond.instrs:
        cm = re.match(r"[su](?:32|64)\[\]\s*constant\((\d+)\)", ins.text)
        if cm:
            best = max(best, int(cm.group(1)))
    return best


_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')


def _trip_count(while_ins: Instr, comps: dict[str, Computation]) -> int:
    """Trip count: backend_config known_trip_count, else condition constant."""
    m = _TRIP_RE.search(while_ins.text)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%?([\w.\-]+)", while_ins.text)
    if cm and cm.group(1) in comps:
        return _trip_count_from_cond(comps[cm.group(1)])
    return 1


def _find_entry(comps: dict) -> str:
    if ENTRY_KEY in comps:
        return comps[ENTRY_KEY]
    # fallback: a computation never referenced as body/cond/calls target
    referenced: set[str] = set()
    for comp in comps.values():
        if isinstance(comp, str):
            continue
        for ins in comp.instrs:
            for key in ("body=", "condition=", "calls=", "to_apply="):
                for mm in re.finditer(key + r"%?([\w.\-]+)", ins.text):
                    referenced.add(mm.group(1))
    candidates = [c for c in comps if c not in referenced and c != ENTRY_KEY]
    return candidates[0] if candidates else next(iter(comps))


def computation_weights(comps: dict[str, Computation],
                        entry: Optional[str] = None) -> dict[str, float]:
    """Execution multiplicity of each computation (while-aware)."""
    if entry is None:
        entry = _find_entry(comps)
    comps = {k: v for k, v in comps.items() if not isinstance(v, str)}

    weights: dict[str, float] = defaultdict(float)

    def visit(name: str, w: float, depth=0):
        if name not in comps or depth > 50:
            return
        weights[name] += w
        for ins in comps[name].instrs:
            if ins.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.text)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.text)
                trips = _trip_count(ins, comps)
                if bm:
                    visit(bm.group(1), w * trips, depth + 1)
                if cm:
                    visit(cm.group(1), w * (trips + 1), depth + 1)
            else:
                for key in ("calls=", "to_apply="):
                    mm = re.search(key + r"%?([\w.\-]+)", ins.text)
                    if mm:
                        visit(mm.group(1), w, depth + 1)
                if ins.op == "conditional":
                    for mm in re.finditer(
                            r"(?:true_computation|false_computation|"
                            r"branch_computations=\{[^}]*)=?%?([\w.\-]+)",
                            ins.text):
                        visit(mm.group(1), w, depth + 1)
    visit(entry, 1.0)
    return dict(weights)


def _dot_flops(ins: Instr, symtab: dict[str, Instr]) -> float:
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.text)
    if not m or not ins.operands:
        return 2.0 * ins.result_elems
    lhs = symtab.get(ins.operands[0])
    if lhs is None or not lhs.shapes:
        return 2.0 * ins.result_elems
    dims = lhs.shapes[0][1]
    contract = 1
    for d in m.group(1).split(","):
        if d and int(d) < len(dims):
            contract *= dims[int(d)]
    return 2.0 * ins.result_elems * contract


def _conv_flops(ins: Instr, symtab: dict[str, Instr]) -> float:
    wm = re.search(r"window=\{size=([0-9x]+)", ins.text)
    window = 1
    if wm:
        for d in wm.group(1).split("x"):
            window *= int(d)
    in_feat = 1
    if len(ins.operands) >= 2:
        ker = symtab.get(ins.operands[1])
        # kernel input-feature dim ≈ total kernel elems / (window · out_feat)
        if ker is not None and ker.result_elems and window and ker.shapes:
            out_feat_guess = ker.shapes[0][1][-1]
            in_feat = max(1, ker.result_elems
                          // max(1, window * out_feat_guess))
    return 2.0 * ins.result_elems * window * in_feat


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "conditional", "call", "custom-call",
                   "copy-start", "copy-done", ""}


@dataclasses.dataclass
class HloTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_type: dict = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    loop_weighted: bool = True


def analyze(hlo: str) -> HloTotals:
    comps = parse_module(hlo)
    weights = computation_weights(comps)
    totals = HloTotals()
    for cname, comp in comps.items():
        if isinstance(comp, str):  # ENTRY_KEY sentinel
            continue
        w = weights.get(cname, 0.0)
        if w == 0.0:
            continue
        symtab = {i.name: i for i in comp.instrs}
        for ins in comp.instrs:
            if ins.op == "dot":
                totals.flops += w * _dot_flops(ins, symtab)
            elif ins.op == "convolution":
                totals.flops += w * _conv_flops(ins, symtab)
            for cop in COLLECTIVES:
                if ins.op.startswith(cop) and not ins.op.endswith("-done"):
                    totals.collective_bytes += w * ins.result_bytes
                    totals.collective_by_type[cop] += w * ins.result_bytes
            if comp.is_fusion_body:
                continue  # fusion internals don't touch HBM
            if ins.op in _SKIP_BYTES_OPS:
                continue
            opnd_bytes = sum(symtab[o].result_bytes for o in ins.operands
                             if o in symtab)
            totals.hbm_bytes += w * (ins.result_bytes + opnd_bytes)
    return totals
