"""Launchers: production mesh, dry-run, training and serving CLIs.

NOTE: repro.launch.dryrun must be imported/run first in its own process —
it sets XLA_FLAGS for 512 placeholder devices before any JAX import.
"""
from repro.launch.mesh import data_axes, make_host_mesh, make_production_mesh

__all__ = ["data_axes", "make_host_mesh", "make_production_mesh"]
