"""Launchers: production mesh, dry-run, training and serving CLIs.

NOTE: repro.launch.dryrun must be imported/run first in its own process —
it sets XLA_FLAGS for 512 placeholder devices before any JAX import.
"""
from repro.launch.mesh import (CLIENT_AXIS, client_mesh_size, data_axes,
                               init_distributed, make_client_mesh,
                               make_host_mesh, make_production_mesh)

__all__ = ["CLIENT_AXIS", "client_mesh_size", "data_axes",
           "init_distributed", "make_client_mesh", "make_host_mesh",
           "make_production_mesh"]
