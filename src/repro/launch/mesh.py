"""Production mesh builders.

Functions, not module constants: importing this module never touches JAX
device state (the dry-run sets XLA_FLAGS before any JAX import).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis joins the
data/FSDP product so cross-pod traffic is gradient/param-aggregation only.

FL round engine: :func:`make_client_mesh` builds the mesh the federated
drivers shard over (``FLConfig(mesh=...)``; see federated/server.py):

- ``make_client_mesh(D)`` — 1-D ``'clients'`` mesh: the stacked client axis
  of every round is split D ways (data parallelism over clients).
- ``make_client_mesh(D, model=M)`` — 2-D ``('clients', 'model')`` mesh of
  D total devices (D/M × M): in addition to the client split, every
  parameter leaf and every row of the error-feedback residual store is
  FSDP-sharded 1/M per device along its largest divisible dim
  (:func:`repro.launch.sharding.fl_param_specs`), so the at-rest memory
  cliffs — the N × model-size residual store first — shrink by M.

On CPU hosts, forced virtual devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) make the same code
path testable without accelerators.
"""
from __future__ import annotations

import jax
import numpy as np

CLIENT_AXIS = "clients"
MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes forming the batch/FSDP product ('pod' included when present)."""
    names = mesh.axis_names
    return tuple(a for a in names if a != "model")


def make_host_mesh(data: int = 2, model: int = 2):
    """Tiny mesh over host devices for CI-scale distribution tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def init_distributed(coordinator_address: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None,
                     local_device_ids=None) -> dict:
    """Idempotent ``jax.distributed.initialize`` wrapper.

    Call ONCE per process, before any other JAX use, to let the 'clients'
    mesh axis span hosts (``make_client_mesh(processes=...)``). Arguments
    left ``None`` fall back to jax's own environment autodetection
    (``JAX_COORDINATOR_ADDRESS`` etc. / cluster plugins). Already
    initialized (``jax.process_count() > 1`` or a repeated call) is a
    no-op, so drivers and benchmarks can call it unconditionally.

    Returns ``{"process_id", "process_count", "device_count"}`` for
    logging. Raises ``RuntimeError`` on backends where multi-process init
    is unsupported — callers that only *prefer* distributed mode (e.g.
    ``benchmarks/dist_smoke.py``) catch it and fall back to single-process.
    """
    try:
        # probe WITHOUT touching the backend: jax.process_count() would
        # initialize XLA, after which jax.distributed.initialize refuses
        # to run ("must be called before any JAX computations")
        from jax._src.distributed import global_state
        already = global_state.client is not None
    except ImportError:        # private module moved: just attempt init
        already = False
    if not already:
        kwargs = {k: v for k, v in
                  (("coordinator_address", coordinator_address),
                   ("num_processes", num_processes),
                   ("process_id", process_id),
                   ("local_device_ids", local_device_ids))
                  if v is not None}
        try:
            jax.distributed.initialize(**kwargs)
        except RuntimeError as e:
            # a repeated initialize is the one benign failure
            if "already" not in str(e).lower():
                raise
    return {"process_id": jax.process_index(),
            "process_count": jax.process_count(),
            "device_count": len(jax.devices())}


def make_client_mesh(num_devices: int | None = None, model: int = 1,
                     processes: int | None = None):
    """Device mesh for the FL round engine.

    ``num_devices`` counts the TOTAL devices used (``None`` = every visible
    device; an explicit count takes the first ``num_devices``, so
    equivalence tests can build submeshes inside one forced-8-device
    process). With ``model=1`` (default) the mesh is the original 1-D
    ``'clients'`` mesh — the stacked client axis is the embarrassingly
    parallel dimension of every round. With ``model=M > 1`` the devices are
    folded into a 2-D ``('clients', 'model')`` mesh of shape
    ``(num_devices // M, M)``: the 'clients' factor still splits the round's
    client stack, while the 'model' factor FSDP-shards parameter leaves and
    the error-feedback residual store (see federated/server.py).

    ``processes``: multi-host mode. After :func:`init_distributed`,
    ``jax.devices()`` is the GLOBAL device list; passing the expected
    process count builds the mesh with ``jax.make_mesh`` over all global
    devices, whose device ordering keeps each host's local devices
    contiguous on the 'clients' axis — so a hierarchical aggregation tier
    with ``group_size = devices_per_host``
    (``FLConfig(agg_group_size=...)``) reduces intra-host first and only
    group leaders' ring traffic crosses the network. ``processes=None``/1
    keeps the original single-process construction byte-identical.
    """
    devs = jax.devices()
    n = len(devs) if num_devices is None else num_devices
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"make_client_mesh: asked for {n} devices, have {len(devs)} "
            "(on CPU, force more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    multi = processes is not None and processes > 1
    if multi and jax.process_count() != processes:
        raise ValueError(
            f"make_client_mesh: processes={processes} but "
            f"jax.process_count()={jax.process_count()} — call "
            "repro.launch.mesh.init_distributed() in every process first")
    if model <= 1:
        if multi and n == len(devs):
            return jax.make_mesh((n,), (CLIENT_AXIS,))
        return jax.sharding.Mesh(np.asarray(devs[:n]), (CLIENT_AXIS,))
    if n % model:
        raise ValueError(
            f"make_client_mesh: model={model} must divide the total device "
            f"count {n} (mesh shape is (clients={n}//{model}, model={model}))")
    if multi and n == len(devs):
        return jax.make_mesh((n // model, model), (CLIENT_AXIS, MODEL_AXIS))
    grid = np.asarray(devs[:n]).reshape(n // model, model)
    return jax.sharding.Mesh(grid, (CLIENT_AXIS, MODEL_AXIS))


def client_mesh_size(mesh) -> int:
    """Devices on the ``'clients'`` axis (validates the axis exists)."""
    if CLIENT_AXIS not in mesh.axis_names:
        raise ValueError(
            f"mesh has axes {mesh.axis_names}; FL client sharding needs a "
            f"{CLIENT_AXIS!r} axis (see make_client_mesh)")
    return int(mesh.shape[CLIENT_AXIS])


def model_mesh_size(mesh) -> int:
    """Devices on the ``'model'`` axis; 1 when the mesh has no such axis
    (1-D client meshes keep params fully replicated)."""
    if MODEL_AXIS not in mesh.axis_names:
        return 1
    return int(mesh.shape[MODEL_AXIS])


def replicated_rng(fn, mesh):
    """Wrap an RNG-consuming computation so its drawn values are
    bit-identical to the single-device lowering on any ``mesh``.

    Under the default non-partitionable threefry
    (``jax_threefry_partitionable=False``), XLA's SPMD partitioner is free
    to shard a random op's lowering across devices — which silently
    *changes* (and can bias) the drawn values, because the counter
    assignment is rewritten per shard; an output
    ``with_sharding_constraint`` does not stop it from computing the bits
    sharded first. Running the draw inside a ``shard_map`` whose in/out
    specs are fully replicated leaves the partitioner nothing to split:
    every device executes the exact single-device program. Inputs and
    outputs must be small and wanted replicated (participant ids, batch
    indices — the FL engine's case).
    """
    from jax.sharding import PartitionSpec
    return shard_map_norep(fn, mesh, in_specs=PartitionSpec(),
                           out_specs=PartitionSpec())


def shard_map_norep(f, mesh, in_specs, out_specs):
    """Version-compatible ``shard_map`` with replication checking off.

    jax moved ``jax.experimental.shard_map`` to top-level ``jax.shard_map``
    (renaming ``check_rep`` to ``check_vma``); CI's latest-jax leg needs the
    new spelling while the pinned 0.4.x container needs the old one. The
    replication check is disabled in both: the static checker cannot follow
    the axis_index-based row slicing the sharded FL round uses, and output
    replication is instead covered by equivalence tests
    (tests/test_shard_engine.py).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)
