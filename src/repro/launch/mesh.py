"""Production mesh builders.

Functions, not module constants: importing this module never touches JAX
device state (the dry-run sets XLA_FLAGS before any JAX import).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis joins the
data/FSDP product so cross-pod traffic is gradient/param-aggregation only.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes forming the batch/FSDP product ('pod' included when present)."""
    names = mesh.axis_names
    return tuple(a for a in names if a != "model")


def make_host_mesh(data: int = 2, model: int = 2):
    """Tiny mesh over host devices for CI-scale distribution tests."""
    return jax.make_mesh((data, model), ("data", "model"))
