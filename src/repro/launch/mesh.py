"""Production mesh builders.

Functions, not module constants: importing this module never touches JAX
device state (the dry-run sets XLA_FLAGS before any JAX import).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis joins the
data/FSDP product so cross-pod traffic is gradient/param-aggregation only.

FL round engine: :func:`make_client_mesh` builds the 1-D ``'clients'`` mesh
the federated drivers shard the stacked client axis over
(``FLConfig(mesh=...)``; see federated/server.py). On CPU hosts, forced
virtual devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
make the same code path testable without accelerators.
"""
from __future__ import annotations

import jax
import numpy as np

CLIENT_AXIS = "clients"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes forming the batch/FSDP product ('pod' included when present)."""
    names = mesh.axis_names
    return tuple(a for a in names if a != "model")


def make_host_mesh(data: int = 2, model: int = 2):
    """Tiny mesh over host devices for CI-scale distribution tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_client_mesh(num_devices: int | None = None):
    """1-D ``'clients'`` mesh for sharding the FL round engine's stacked
    client axis (the embarrassingly parallel dimension of every round).

    ``num_devices=None`` uses every visible device; an explicit count takes
    the first ``num_devices`` (so equivalence tests can build 1/2/4-device
    submeshes inside one forced-8-device process).
    """
    devs = jax.devices()
    n = len(devs) if num_devices is None else num_devices
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"make_client_mesh: asked for {n} devices, have {len(devs)} "
            "(on CPU, force more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.sharding.Mesh(np.asarray(devs[:n]), (CLIENT_AXIS,))


def client_mesh_size(mesh) -> int:
    """Devices on the ``'clients'`` axis (validates the axis exists)."""
    if CLIENT_AXIS not in mesh.axis_names:
        raise ValueError(
            f"mesh has axes {mesh.axis_names}; FL client sharding needs a "
            f"{CLIENT_AXIS!r} axis (see make_client_mesh)")
    return int(mesh.shape[CLIENT_AXIS])


def shard_map_norep(f, mesh, in_specs, out_specs):
    """Version-compatible ``shard_map`` with replication checking off.

    jax moved ``jax.experimental.shard_map`` to top-level ``jax.shard_map``
    (renaming ``check_rep`` to ``check_vma``); CI's latest-jax leg needs the
    new spelling while the pinned 0.4.x container needs the old one. The
    replication check is disabled in both: the static checker cannot follow
    the axis_index-based row slicing the sharded FL round uses, and output
    replication is instead covered by equivalence tests
    (tests/test_shard_engine.py).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)
