"""Serving launcher: batched prefill + decode of a (FedLDF-trained) global
model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-vl-2b \
        --reduced --batch 4 --prompt-len 32 --steps 16 [--ckpt out/global.npz]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree
from repro.configs import ARCH_IDS, get_config
from repro.models import decode as dec
from repro.models import transformer as tf


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), param_dtype="float32",
                                  compute_dtype="float32")
    key = jax.random.PRNGKey(args.seed)
    params = (load_pytree(args.ckpt) if args.ckpt
              else tf.init_params(key, cfg))

    b, s = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    enc = (jax.random.normal(key, (b, s, cfg.frontend_dim),
                             dtype=jnp.float32) if cfg.is_encdec else None)

    prefill = jax.jit(lambda p, t: dec.prefill(
        p, cfg, t, enc_inputs=enc, max_len=s + args.steps))
    step = jax.jit(lambda p, t, c: dec.decode_step(p, cfg, t, c))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t1 = time.time()
    toks = jnp.argmax(logits, -1)[:, None]
    out = [toks]
    skey = key
    for i in range(args.steps - 1):
        logits, cache = step(params, toks, cache)
        skey, sub = jax.random.split(skey)
        if args.temperature > 0:
            toks = jax.random.categorical(
                sub, logits / args.temperature, axis=-1)[:, None]
        else:
            toks = jnp.argmax(logits, -1)[:, None]
        out.append(toks)
    jax.block_until_ready(out[-1])
    t2 = time.time()

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} batch={b} prompt={s} steps={args.steps}")
    print(f"prefill: {t1-t0:.3f}s  decode: {(t2-t1)/max(1,args.steps-1)*1e3:.1f}ms/tok")
    for i in range(min(b, 2)):
        print(f"  seq{i}: {gen[i][:16].tolist()}...")


if __name__ == "__main__":
    main()
