import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run profiler: rank the heaviest (loop-weighted) collectives and HBM
consumers in a compiled (arch × shape) program, with JAX source attribution
from HLO metadata. This is the 'profile' step of the §Perf hypothesis loop
(no real hardware — the lowered IR is the profile).

    PYTHONPATH=src python -m repro.launch.inspect --arch mamba2-780m \
        --shape prefill_32k [--variant X] [--top 15]
"""
import argparse
import re

from repro.launch import hloparse


def top_collectives(hlo: str, top: int = 15):
    comps = hloparse.parse_module(hlo)
    weights = hloparse.computation_weights(comps)
    rows = []
    for cname, comp in comps.items():
        if isinstance(comp, str):
            continue
        w = weights.get(cname, 0.0)
        if w == 0:
            continue
        for ins in comp.instrs:
            for cop in hloparse.COLLECTIVES:
                if ins.op.startswith(cop) and not ins.op.endswith("-done"):
                    m = re.search(r'op_name="([^"]*)"', ins.text)
                    rows.append((w * ins.result_bytes, cop, w,
                                 ins.result_bytes,
                                 (m.group(1) if m else "?")[:110]))
    rows.sort(reverse=True)
    return rows[:top]


def top_hbm(hlo: str, top: int = 15):
    comps = hloparse.parse_module(hlo)
    weights = hloparse.computation_weights(comps)
    rows = []
    for cname, comp in comps.items():
        if isinstance(comp, str) or comp.is_fusion_body:
            continue
        w = weights.get(cname, 0.0)
        if w == 0:
            continue
        symtab = {i.name: i for i in comp.instrs}
        for ins in comp.instrs:
            if ins.op in hloparse._SKIP_BYTES_OPS:
                continue
            opnd = sum(symtab[o].result_bytes for o in ins.operands
                       if o in symtab)
            m = re.search(r'op_name="([^"]*)"', ins.text)
            rows.append((w * (ins.result_bytes + opnd), ins.op, w,
                         (m.group(1) if m else "?")[:110]))
    rows.sort(reverse=True)
    return rows[:top]


def top_flops(hlo: str, top: int = 15):
    comps = hloparse.parse_module(hlo)
    weights = hloparse.computation_weights(comps)
    rows = []
    for cname, comp in comps.items():
        if isinstance(comp, str):
            continue
        w = weights.get(cname, 0.0)
        if w == 0:
            continue
        symtab = {i.name: i for i in comp.instrs}
        for ins in comp.instrs:
            if ins.op == "dot":
                f = hloparse._dot_flops(ins, symtab)
                m = re.search(r'op_name="([^"]*)"', ins.text)
                rows.append((w * f, w, f,
                             (m.group(1) if m else "?")[:110]))
    rows.sort(reverse=True)
    return rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import lower_one
    roof, compiled = lower_one(args.arch, args.shape,
                               multi_pod=args.multi_pod,
                               variant=args.variant, verbose=True)
    hlo = compiled.as_text()
    print("\n=== top FLOP contributors (loop-weighted, per device) ===")
    for f, w, raw, src in top_flops(hlo, args.top):
        print(f"{f/1e12:10.2f}TF  w={w:8.0f} raw={raw/1e9:10.2f}GF  {src}")
    print("\n=== top collectives (loop-weighted bytes/device) ===")
    for b, op, w, raw, src in top_collectives(hlo, args.top):
        print(f"{b/1e9:10.2f}GB  {op:20s} w={w:8.0f} raw={raw/1e6:8.1f}MB  {src}")
    print("\n=== top HBM consumers (loop-weighted operand+result bytes) ===")
    for b, op, w, src in top_hbm(hlo, args.top):
        print(f"{b/1e9:10.2f}GB  {op:20s} w={w:8.0f}  {src}")


if __name__ == "__main__":
    main()
