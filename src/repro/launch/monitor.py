"""Telemetry ledger monitor: render FL round ledgers in the terminal.

    PYTHONPATH=src python -m repro.launch.monitor runs/ledger.jsonl
    PYTHONPATH=src python -m repro.launch.monitor ledger.jsonl --run 2
    PYTHONPATH=src python -m repro.launch.monitor ledger.jsonl --bins 40

Consumes the JSONL event ledger written by ``run_training`` /
``run_training_scan`` under ``FLConfig(telemetry=TelemetryConfig(
ledger_path=...))`` (see :mod:`repro.telemetry.ledger`) and renders, per
run segment:

- the run header (algo, driver, rounds, mesh, seed);
- a **per-layer divergence heat table** — one row per layer unit, the
  tapped ``div_mean`` trajectory binned over rounds and drawn as a
  sparkline, plus min/max of the layer's mean divergence (which layers
  FedLDF's Eq. 4 feedback considers hot, and when);
- a **per-layer selection heat table** — ``sel_count`` (how many of the
  K participants uploaded each layer, per round, binned the same way)
  with each layer's aggregate upload share;
- strategy-state trajectories for any tapped ``state_*`` vectors
  (FedLAMA's interval/ttl, EF residual norms, ...);
- a **bytes-per-round summary**: uplink payload/feedback/total and
  savings vs FedAvg, from the per-round comm profiles — plus, for mesh
  runs, the aggregation-tier traffic split (intra-group vs cross-group vs
  busiest-host bytes of the flat or two-tier reduce) — plus loss start→
  end, wall-clock and peak-memory stats when sampled, and eval points.

Stdlib + numpy only (no JAX) so it can run on a login node against
ledgers produced anywhere.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.telemetry import read_ledger, split_runs

_SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values, lo=None, hi=None) -> str:
    """Unicode sparkline of a 1-D series (empty-safe, NaN-safe)."""
    v = np.asarray(values, dtype=np.float64)
    if v.size == 0:
        return ""
    finite = np.isfinite(v)
    if not finite.any():
        return " " * v.size
    lo = np.nanmin(v[finite]) if lo is None else lo
    hi = np.nanmax(v[finite]) if hi is None else hi
    span = (hi - lo) or 1.0
    out = []
    for x in v:
        if not np.isfinite(x):
            out.append(" ")
            continue
        idx = int((x - lo) / span * (len(_SPARK) - 1) + 0.5)
        out.append(_SPARK[max(0, min(len(_SPARK) - 1, idx))])
    return "".join(out)


def bin_series(values, bins: int):
    """Mean-pool a 1-D series into at most ``bins`` buckets (for heat
    tables over long runs); shorter series pass through unchanged."""
    v = np.asarray(values, dtype=np.float64)
    if v.size <= bins:
        return v
    edges = np.linspace(0, v.size, bins + 1).astype(int)
    return np.array([v[a:b].mean() if b > a else np.nan
                     for a, b in zip(edges[:-1], edges[1:])])


def _tap_matrix(rounds_rec, name):
    """Stack tap ``name`` over rounds -> (T, ...) array, or None if the
    tap is absent (taps disabled, or strategy without it)."""
    rows = [r.get("taps") or {} for r in rounds_rec]
    if not rows or name not in rows[0]:
        return None
    return np.asarray([row[name] for row in rows])


def _unit_names(meta, width):
    units = (meta or {}).get("units")
    if not units or len(units) != width:
        units = [f"unit{i}" for i in range(width)]
    return [str(u) for u in units]


def _heat_table(mat, units, bins, value_fmt, out, right_label):
    """One row per layer unit: sparkline of its (T,) series + extremes."""
    w = max(len(u) for u in units)
    for u, series in zip(units, mat.T):
        binned = bin_series(series, bins)
        print(f"    {u:<{w}}  {sparkline(binned)}  "
              f"min {value_fmt.format(np.nanmin(series))}  "
              f"max {value_fmt.format(np.nanmax(series))}"
              f"{right_label(series)}", file=out)


def render_run(seg, out=sys.stdout, bins: int = 60) -> None:
    """Render one run segment (a ``split_runs`` entry)."""
    meta, rounds_rec, evals = seg["meta"], seg["rounds"], seg["evals"]
    if meta:
        mesh = meta.get("mesh")
        mesh_s = ("x".join(str(v) for v in mesh.values())
                  if mesh else "single-device")
        agg = meta.get("agg")
        if agg and agg.get("tiers", 1) > 1:
            mesh_s += (f" (2-tier agg: {agg['num_groups']} groups of "
                       f"{agg['group_size']})")
        if meta.get("shard_samples"):
            mesh_s += " sample-sharded"
        print(f"== run {meta.get('run_id') or meta.get('algo', '?')} — "
              f"algo={meta.get('algo', '?')} driver={meta.get('driver', '?')}"
              f" mode={meta.get('mode', '?')} mesh={mesh_s} "
              f"seed={meta.get('seed', '?')} "
              f"K={meta.get('clients_per_round', '?')}/"
              f"N={meta.get('num_clients', '?')} "
              f"n={meta.get('top_n', '?')}", file=out)
    else:
        print("== run (no header)", file=out)
    if not rounds_rec:
        print("    (no round records)", file=out)
        return
    t0, t1 = rounds_rec[0]["round"], rounds_rec[-1]["round"]
    print(f"   rounds {t0}..{t1} ({len(rounds_rec)} records)", file=out)

    # ---- per-layer divergence heat table (Eq. 3/4 inputs) ----
    div = _tap_matrix(rounds_rec, "div_mean")
    if div is not None:
        units = _unit_names(meta, div.shape[1])
        print("   per-layer mean divergence (rows=layers, cols=rounds):",
              file=out)
        _heat_table(div, units, bins, "{:9.3e}", out, lambda s: "")

    # ---- per-layer selection heat table ----
    sel = _tap_matrix(rounds_rec, "sel_count")
    if sel is not None:
        units = _unit_names(meta, sel.shape[1])
        total = sel.sum()
        print("   per-layer uploads (sel_count; share = fraction of all "
              "layer-uploads):", file=out)
        _heat_table(sel, units, bins, "{:5.1f}", out,
                    lambda s: f"  share {s.sum() / max(total, 1): .3f}")

    # ---- strategy-state trajectories (FedLAMA intervals, EF norms, ...)
    first_taps = rounds_rec[0].get("taps") or {}
    for name in sorted(first_taps):
        if not name.startswith("state_"):
            continue
        mat = _tap_matrix(rounds_rec, name)
        if mat is None:
            continue
        if mat.ndim == 1:
            print(f"   {name}: {sparkline(bin_series(mat, bins))}  "
                  f"start {mat[0]:.3e} end {mat[-1]:.3e}", file=out)
        else:
            units = _unit_names(meta, mat.shape[1])
            print(f"   {name} per layer:", file=out)
            _heat_table(mat, units, bins, "{:8.2f}", out, lambda s: "")

    # ---- bytes-per-round + loss/system summary ----
    comm = [r["comm"] for r in rounds_rec]
    up_total = np.array([c["uplink_total"] for c in comm])
    up_pay = np.array([c.get("uplink_payload", np.nan) for c in comm])
    up_fb = np.array([c.get("uplink_feedback", np.nan) for c in comm])
    base = np.array([c["fedavg_uplink"] for c in comm])
    print(f"   bytes/round: uplink {up_total.mean() / 1e6:.3f}MB avg "
          f"(payload {np.nanmean(up_pay) / 1e6:.3f} + feedback "
          f"{np.nanmean(up_fb) / 1e6:.3f}), "
          f"cumulative {rounds_rec[-1]['uplink_cum_bytes'] / 1e6:.1f}MB, "
          f"savings vs fedavg {1 - up_total.sum() / base.sum():.3f}",
          file=out)
    print(f"   uplink/round: {sparkline(bin_series(up_total, bins))}",
          file=out)
    # aggregation-tier traffic split (mesh rounds; static per config)
    if comm and "agg_cross_bytes" in comm[-1]:
        c = comm[-1]
        tiers = int(c.get("agg_tiers", 1))
        print(f"   agg traffic/round ({tiers}-tier reduce): intra-group "
              f"{c.get('agg_intra_bytes', 0.0) / 1e6:.3f}MB, cross-group "
              f"{c['agg_cross_bytes'] / 1e6:.3f}MB, busiest host "
              f"{c.get('agg_cross_bytes_per_host', 0.0) / 1e6:.3f}MB",
              file=out)
    loss = np.array([r["loss"] for r in rounds_rec])
    print(f"   loss: {sparkline(bin_series(loss, bins))}  "
          f"{loss[0]:.4f} -> {loss[-1]:.4f}", file=out)
    wall = np.array([r["wall_s"] or np.nan for r in rounds_rec],
                    dtype=np.float64)
    if np.isfinite(wall).any():
        print(f"   wall/round: median {np.nanmedian(wall) * 1e3:.1f}ms "
              f"(p90 {np.nanpercentile(wall, 90) * 1e3:.1f}ms)", file=out)
    mem = [r.get("mem_peak_bytes") for r in rounds_rec]
    mem = [m for m in mem if m]
    if mem:
        print(f"   peak device memory: {max(mem) / 1e6:.1f}MB", file=out)
    for ev in evals:
        print(f"   eval @ round {ev['round']:4d}: test_err "
              f"{ev['test_error']:.4f} "
              f"(uplink {ev['uplink_cum_bytes'] / 1e6:.1f}MB)", file=out)


def render(path: str, out=sys.stdout, bins: int = 60,
           run: int | None = None) -> int:
    """Render every run segment in a ledger file (or just segment ``run``,
    0-based). Returns the number of segments rendered."""
    segs = split_runs(read_ledger(path))
    if not segs:
        print(f"{path}: no ledger records", file=out)
        return 0
    if run is not None:
        segs = [segs[run]]
    for seg in segs:
        render_run(seg, out=out, bins=bins)
    return len(segs)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="render an FL telemetry JSONL ledger "
                    "(repro.telemetry) as terminal heat tables")
    ap.add_argument("ledger", help="path to a telemetry JSONL ledger")
    ap.add_argument("--run", type=int, default=None,
                    help="render only this run segment (0-based; "
                         "default: all segments in the file)")
    ap.add_argument("--bins", type=int, default=60,
                    help="max sparkline width in round-buckets")
    args = ap.parse_args(argv)
    render(args.ledger, bins=args.bins, run=args.run)


if __name__ == "__main__":
    main()
