"""Named performance variants — the §Perf hillclimb levers.

Each variant is (config transform, sharding-override builder). The dry-run
applies a variant on top of the baseline and re-lowers; EXPERIMENTS.md §Perf
records baseline → variant deltas per roofline term.

Baseline auto-sharding recap (launch/sharding.py): largest divisible dim →
'model', next → data axes; caches: W(seq) → 'model' and — because of the
max-size/tie rule — head_dim often lands on 'data' instead of batch, which
the SPMD partitioner then has to undo around the ring-buffer update
(observed "involuntary full rematerialization" warnings). The variants below
are the hypotheses formed from reading that lowered IR.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Variant:
    name: str
    hypothesis: str
    cfg_fn: Callable[[ModelConfig], ModelConfig] = lambda c: c
    overrides_fn: Optional[Callable[[ModelConfig, tuple], dict]] = None
    # overrides_fn(cfg, data_axes) -> {path-regex: PartitionSpec}


def _remat(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, remat_blocks=True)


def _remat_flash_tune(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, remat_blocks=True, attn_chunk=4096,
                               attn_probs_bf16=True)


def _head_pad(cfg: ModelConfig) -> ModelConfig:
    """Megatron-style head padding: round heads up to the model-axis size so
    attention shards instead of replicating (16× redundant compute for
    hymba's 25H/5KV). Adds dead parameters — a perf variant, not the
    faithful config (analogous to the vocab padding we always do)."""
    if cfg.num_heads % 16 == 0 and (cfg.num_kv_heads % 16 == 0
                                    or cfg.num_kv_heads == 0):
        return cfg
    nh = -(-cfg.num_heads // 16) * 16
    nkv = cfg.num_kv_heads
    while nh % nkv or nkv % 2 and nkv < nh:  # keep GQA divisibility
        nkv += 1
    return dataclasses.replace(cfg, num_heads=nh, num_kv_heads=nkv)


def _remat_flash_headpad(cfg: ModelConfig) -> ModelConfig:
    return _head_pad(_remat_flash_tune(cfg))


def _cache_batch_overrides(cfg: ModelConfig, daxes) -> dict:
    """Pin KV cache to (L, B→data, W, KV, hd→model): keeps the ring-buffer
    dynamic-update local to a device (no resharding inside the decode scan).
    hd=128 divides 'model'=16; B must divide data (decode_32k: 128/16 ✓)."""
    d = daxes if len(daxes) > 1 else daxes[0]
    return {
        r"^(k|v)$": P(None, d, None, None, "model"),
        r"^(cross_k|cross_v)$": P(None, d, None, None, "model"),
    }


def _cache_seq_overrides(cfg: ModelConfig, daxes) -> dict:
    """Pin KV cache W→data (flash-decoding style sequence parallelism) with
    hd→model; for long_500k (B=1) the batch axis cannot shard, so spreading
    the window over 'data' is the only way to use those chips."""
    d = daxes if len(daxes) > 1 else daxes[0]
    return {
        r"^(k|v)$": P(None, None, d, None, "model"),
        r"^(cross_k|cross_v)$": P(None, None, d, None, "model"),
    }


def _expert_parallel_overrides(cfg: ModelConfig, daxes) -> dict:
    """Experts → 'model' (true expert parallelism: each chip column owns
    E/16 experts; the token reshard becomes the all-to-all) instead of the
    baseline's tensor-parallel-within-every-expert layout."""
    d = daxes if len(daxes) > 1 else daxes[0]
    return {
        r"moe/w_(gate|up)$": P(None, "model", d, None),
        r"moe/w_down$": P(None, "model", None, d),
    }


def _ssm_proj_overrides(cfg: ModelConfig, daxes) -> dict:
    """SSM projections: column-parallel in_proj (replicate D, shard the fused
    zxbcdt output on 'model') and row-parallel out_proj. Removes the
    per-layer all-reduce the baseline FSDP sharding puts after the in_proj
    contraction (profiled: 2×81 GB/dev on mamba2 prefill_32k)."""
    return {
        # leaves live under the stacked 'blocks' key: leading depth dim
        r"ssm/in_proj$": P(None, None, "model"),
        r"ssm/out_proj$": P(None, "model", None),
        r"ssm/conv_w$": P(None, None, "model"),
    }


def _megatron_overrides(cfg: ModelConfig, daxes) -> dict:
    """Classic Megatron column/row-parallel TP for all block weights
    (contraction dims replicated over 'data'): one fwd all-reduce per
    attn/MLP pair instead of one per matmul. Gives up FSDP param sharding
    over 'data' — valid when params/model_axis fits HBM (e.g. 33B bf16 →
    4.1 GB/chip), NOT for 400B-class MoE (see expert_parallel instead)."""
    return {
        r"attn/w[qkv]$|mlp/w_(gate|up)$|shared/w_(gate|up)$":
            P(None, None, "model"),
        r"attn/wo$|mlp/w_down$|shared/w_down$": P(None, "model", None),
        r"attn/b[qkv]$": P(None, "model"),
        r"cross/w[qkv]$": P(None, None, "model"),
        r"cross/wo$": P(None, "model", None),
        r"ssm/in_proj$|ssm/conv_w$": P(None, None, "model"),
        r"ssm/out_proj$": P(None, "model", None),
        r"embed/tok$": P("model", None),
        r"final/head$": P(None, "model"),
        r"enc_embed/proj$": P(None, "model"),
    }


VARIANTS: dict[str, Variant] = {
    "megatron": Variant(
        "megatron",
        "Replace FSDP-everywhere with Megatron column/row TP: kills the "
        "per-matmul partial-sum all-reduces the baseline pays on every "
        "FSDP-sharded contraction dim.",
        overrides_fn=_megatron_overrides),
    "remat+flash_tune+megatron": Variant(
        "remat+flash_tune+megatron",
        "All three levers for the dense train pair.",
        cfg_fn=_remat_flash_tune,
        overrides_fn=_megatron_overrides),
    "ssm_proj": Variant(
        "ssm_proj",
        "Column-parallel SSM in_proj (no FSDP on the contraction dim) kills "
        "the post-dot all-reduce; fused-split permutes may remain.",
        overrides_fn=_ssm_proj_overrides),
    "remat": Variant(
        "remat",
        "Block-boundary activation checkpointing cuts train-round HBM "
        "traffic/residency (memory term) at ~1.3× compute; dominant term is "
        "memory, so net win expected.",
        cfg_fn=_remat),
    "cache_batch": Variant(
        "cache_batch",
        "KV cache sharded B→data, hd→model keeps decode-scan ring-buffer "
        "updates device-local; removes the involuntary-remat copies "
        "(collective + memory terms).",
        overrides_fn=_cache_batch_overrides),
    "cache_seq": Variant(
        "cache_seq",
        "KV cache W→data parallelises the 500k-context window across chips "
        "when batch=1 (collective term trades against idle chips).",
        overrides_fn=_cache_seq_overrides),
    "expert_parallel": Variant(
        "expert_parallel",
        "E→model expert parallelism turns per-expert tensor-parallel matmul "
        "fragments into whole-expert local matmuls + one all-to-all; for "
        "top-1/128e the dispatch volume ≪ weight-gather volume.",
        overrides_fn=_expert_parallel_overrides),
    "remat+flash_tune": Variant(
        "remat+flash_tune",
        "After remat, flash-attention probability/carry tensors dominate "
        "HBM traffic under XLA lowering (scores hit HBM, unlike a fused "
        "Pallas kernel). bf16 probabilities halve the biggest tensor; a "
        "4096 KV chunk quarters the o-carry rewrites.",
        cfg_fn=_remat_flash_tune),
    "remat+flash_tune+head_pad": Variant(
        "remat+flash_tune+head_pad",
        "Indivisible head counts (hymba 25H/5KV vs model=16) force "
        "replicated attention compute; padding to 32H/8KV lets GSPMD shard "
        "heads (8-way on KV) — trades dead parameters for 16× less "
        "redundant attention FLOPs.",
        cfg_fn=_remat_flash_headpad),
    "remat+flash_tune+expert_parallel": Variant(
        "remat+flash_tune+expert_parallel",
        "Compose all three levers for the MoE train pair.",
        cfg_fn=_remat_flash_tune,
        overrides_fn=_expert_parallel_overrides),
    "moe_full": Variant(
        "moe_full",
        "400B-MoE composition: EP for experts, Megatron TP for attention "
        "(10 GB/chip replicated — fits), FSDP kept on the shared expert "
        "(full TP replication would need 22 GB/chip > v5e HBM), remat + "
        "flash_tune.",
        cfg_fn=_remat_flash_tune,
        overrides_fn=lambda cfg, daxes: {
            **_expert_parallel_overrides(cfg, daxes),
            r"attn/w[qkv]$": P(None, None, "model"),
            r"attn/wo$": P(None, "model", None),
            r"embed/tok$": P("model", None),
            r"final/head$": P(None, "model"),
        }),
    "remat+expert_parallel": Variant(
        "remat+expert_parallel",
        "Remat fixed the memory term; the dominant term is now collective "
        "(expert-weight gathers). E→model expert parallelism keeps expert "
        "weights local and moves only the top-1 token dispatch.",
        cfg_fn=_remat,
        overrides_fn=_expert_parallel_overrides),
    "remat+cache_batch": Variant(
        "remat+cache_batch",
        "Compose the two wins (train shapes also carry no KV cache, so this "
        "equals remat there; kept for decode+train sweeps).",
        cfg_fn=_remat,
        overrides_fn=_cache_batch_overrides),
}


def apply_variant(name: str, cfg: ModelConfig, daxes) -> tuple[ModelConfig,
                                                               Optional[dict]]:
    v = VARIANTS[name]
    cfg2 = v.cfg_fn(cfg)
    ov = v.overrides_fn(cfg2, daxes) if v.overrides_fn else None
    return cfg2, ov
