"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), TPU v5e constants:

    compute    = HLO_FLOPs / (chips × 197e12)         [bf16 peak]
    memory     = HLO_bytes / (chips × 819e9)          [HBM BW]
    collective = collective_bytes / (chips × 50e9)    [ICI per link]

``cost_analysis()`` on the SPMD-partitioned module reports *per-device*
flops/bytes; we multiply by chip count to get the global numerators, so the
terms above reduce to per-device quantities over per-chip rates. Collective
bytes are parsed from the compiled HLO text: the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction (per-device view), scaled by chips for the global numerator.

MODEL_FLOPS (6·N·tokens dense / 6·N_active·tokens MoE; 2·N for inference)
gives the useful-compute ratio — for FedLDF's two-phase recompute mode this
correctly reports ≈0.5, surfacing the protocol-level rematerialization cost.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# result like:  %all-reduce.5 = bf16[8,128,2048]{2,1,0} all-reduce(...)
# or tuples:    (f32[128]{0}, f32[64]{0}) all-gather(...)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes moved by each collective type (result-shape bytes)."""
    out = {op: 0.0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        opname = None
        for op in COLLECTIVE_OPS:
            # match op at the start of the instruction (after result shape)
            if re.search(rf"\b{op}(?:-start|-done)?\(", rhs):
                opname = op
                break
        if opname is None:
            continue
        if f"{opname}-done(" in rhs:
            continue  # counted at -start
        # result shape(s) appear between '=' and the op name
        head = rhs.split(opname)[0]
        for dtype, dims in _SHAPE_RE.findall(head):
            if dtype in _DTYPE_BYTES:
                out[opname] += _shape_bytes(dtype, dims)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_per_device: float
    collective_by_type: dict
    model_flops: float            # global useful FLOPs
    memory_per_device: Optional[dict] = None
    xla_cost_raw: Optional[dict] = None   # cost_analysis() as reported
    # (undercounts while bodies; loop-aware parsed totals above are primary)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_per_device": self.collective_per_device,
            "collective_by_type": self.collective_by_type,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_ratio,
            "memory_per_device": self.memory_per_device,
            "xla_cost_raw": self.xla_cost_raw,
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2)


def model_flops_for(cfg, shape_spec, flcfg=None) -> float:
    """Useful-FLOPs reference (excludes recompute/remat overheads)."""
    n_active = cfg.active_param_count()
    if shape_spec.kind == "train":
        toks = shape_spec.global_batch * shape_spec.seq * (
            flcfg.local_steps if flcfg else 1)
        return 6.0 * n_active * toks
    if shape_spec.kind == "prefill":
        return 2.0 * n_active * shape_spec.global_batch * shape_spec.seq
    # decode: one token per sequence
    return 2.0 * n_active * shape_spec.global_batch
