import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh, print memory/cost analysis, and dump roofline artifacts.

MUST be run as its own process (the XLA flag above is applied before any
other import initialises JAX):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Artifacts: one JSON per (arch, shape, mesh) with per-device FLOPs/bytes,
collective-byte breakdown and the three roofline terms (§Roofline).
"""
import argparse
import json
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import hloparse
from repro.launch import roofline as rl
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.shapes import (FL_TRAIN, SHAPES, adapt_config,
                                 build_program)
from repro.launch.sharding import batch_specs, param_specs, to_named


def _in_shardings(program, mesh, overrides=None):
    shardings = []
    for arg, kind in zip(program.args, program.arg_kinds):
        if kind == "params":
            shardings.append(to_named(param_specs(arg, mesh,
                                                  overrides=overrides), mesh))
        elif kind == "batch":
            client_leading = program.flcfg is not None
            shardings.append(to_named(
                batch_specs(arg, mesh, client_leading=client_leading), mesh))
        elif kind == "cache":
            shardings.append(to_named(param_specs(arg, mesh,
                                                  overrides=overrides), mesh))
        else:  # scalar
            shardings.append(jax.tree.map(
                lambda _: NamedSharding(mesh, P()), arg))
    return tuple(shardings)


def _out_shardings(program, mesh, in_shardings, kind: str):
    out_struct = jax.eval_shape(program.fn, *program.args)
    rep = NamedSharding(mesh, P())
    if kind == "train":
        # (new_params, metrics)
        return (in_shardings[0], jax.tree.map(lambda _: rep, out_struct[1]))
    # (logits, cache)
    cache_like = out_struct[1]
    cache_shard = to_named(param_specs(cache_like, mesh), mesh)
    return (rep, cache_shard)


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              overrides=None, flcfg=FL_TRAIN, variant: str = None,
              verbose: bool = True):
    """Returns (roofline, compiled). Raises on lowering/compile failure."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    shape = SHAPES[shape_name]
    cfg = adapt_config(get_config(arch), shape)
    if variant:
        from repro.launch.variants import apply_variant
        cfg, var_overrides = apply_variant(variant, cfg, data_axes(mesh))
        overrides = {**(var_overrides or {}), **(overrides or {})} or None
    program = build_program(cfg, shape, flcfg)

    with mesh:
        in_sh = _in_shardings(program, mesh, overrides)
        out_sh = _out_shardings(program, mesh, in_sh, shape.kind)
        jitted = jax.jit(program.fn, in_shardings=in_sh, out_shardings=out_sh)
        t0 = time.time()
        lowered = jitted.lower(*program.args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    mem = compiled.memory_analysis()
    cost = hloparse.cost_analysis_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    # Loop-aware totals (while bodies × trip counts) — primary numbers;
    # cost_analysis() counts each while body once (verified) and is kept
    # only as the raw cross-check.
    totals = hloparse.analyze(hlo)

    mem_dict = None
    if mem is not None:
        mem_dict = {a: float(getattr(mem, a)) for a in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes") if hasattr(mem, a)}

    roof = rl.Roofline(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        chips=chips,
        flops_per_device=totals.flops,
        bytes_per_device=totals.hbm_bytes,
        collective_per_device=totals.collective_bytes,
        collective_by_type=totals.collective_by_type,
        model_flops=rl.model_flops_for(cfg, shape, flcfg),
        memory_per_device=mem_dict,
        xla_cost_raw={"flops": float(cost.get("flops", 0.0)),
                      "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
    )
    if verbose:
        print(f"[{arch} × {shape_name} × {roof.mesh}] "
              f"lower {t1-t0:.1f}s compile {t2-t1:.1f}s")
        print("  memory_analysis:", mem_dict)
        print(f"  cost: flops/dev={roof.flops_per_device:.3e} "
              f"bytes/dev={roof.bytes_per_device:.3e} "
              f"coll/dev={roof.collective_per_device:.3e}")
        print(f"  roofline: compute={roof.t_compute*1e3:.2f}ms "
              f"memory={roof.t_memory*1e3:.2f}ms "
              f"collective={roof.t_collective*1e3:.2f}ms "
              f"dominant={roof.dominant} useful={roof.useful_ratio:.3f}")
    return roof, compiled


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="named perf variant from launch/variants.py")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    combos = ([(a, s) for a in ARCH_IDS for s in SHAPES]
              if args.all else [(args.arch, args.shape)])
    failures = []
    for arch, shape_name in combos:
        tag = f"{arch}_{shape_name}_{'2x16x16' if args.multi_pod else '16x16'}"
        if args.variant:
            tag += f"__{args.variant}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"skip {tag} (artifact exists)")
            continue
        try:
            roof, _ = lower_one(arch, shape_name, multi_pod=args.multi_pod,
                                variant=args.variant)
            if args.variant:
                roof.mesh += f"__{args.variant}"
            roof.save(path)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append((tag, repr(e)))
            print(f"FAIL {tag}: {e}")
            traceback.print_exc()
    if failures:
        print("FAILURES:", json.dumps(failures, indent=2))
        return 1
    print("all dry-runs passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
