"""Assigned input shapes → lowerable programs with ShapeDtypeStruct inputs.

Four shapes (assignment):
    train_4k     seq=4 096   global_batch=256   -> fl_round (FedLDF training)
    prefill_32k  seq=32 768  global_batch=32    -> prefill
    decode_32k   seq=32 768  global_batch=128   -> serve_step (1 new token)
    long_500k    seq=524 288 global_batch=1     -> serve_step, sub-quadratic

``long_500k`` policy (DESIGN.md §7): SSM runs natively (recurrent state);
hybrid + all attention archs use the sliding-window variant (window 8 192 —
for hymba this mirrors the real model's SW layers). No arch is skipped.

FL round geometry for train_4k: K=8 sequential clients × 32 local batch
(cross-silo; global_batch = 256), FedLDF top-n=2 (n/K = 0.25 ≈ paper's 0.2).

Audio (enc-dec) sequence placement: ``seq`` is the *audio frame* length; the
decoder side uses min(seq, 1024) text tokens (train/prefill) and a 4 096-
frame cross-attention cache at decode. Recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.federated.server import FLConfig, build_round_scan
from repro.core.units import UnitMap
from repro.models import decode as dec
from repro.models import transformer as tf
from repro.models.config import ModelConfig, dtype_of

Pytree = Any

SLIDING_WINDOW_LONG = 8192
AUDIO_DEC_LEN = 1024
AUDIO_DEC_CROSS = 4096
VLM_PATCHES = 256


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

FL_TRAIN = FLConfig(algo="fedldf", num_clients=64, clients_per_round=8,
                    top_n=2, local_steps=1, lr=0.02, mode="scan",
                    batch_per_client=32)


def adapt_config(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Variant selection per shape (sliding window for long-context)."""
    if (shape.name == "long_500k" and cfg.family != "ssm"
            and not cfg.sliding_window):
        cfg = dataclasses.replace(cfg, sliding_window=SLIDING_WINDOW_LONG)
    return cfg


def params_struct(cfg: ModelConfig) -> Pytree:
    """ShapeDtypeStruct tree of the model params (no allocation)."""
    return jax.eval_shape(
        lambda: tf.init_params(jax.random.PRNGKey(0), cfg))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ----------------------------------------------------------------------
@dataclasses.dataclass
class Program:
    """A lowerable (fn, example-args) bundle."""
    fn: Callable
    args: tuple            # ShapeDtypeStructs (pytrees)
    arg_kinds: tuple       # 'params' | 'batch' | 'cache' | 'scalar' per arg
    flcfg: Optional[FLConfig] = None


def build_program(cfg: ModelConfig, shape: ShapeSpec,
                  flcfg: FLConfig = FL_TRAIN) -> Program:
    cfg = adapt_config(cfg, shape)
    pstruct = params_struct(cfg)
    cdt = dtype_of(cfg.compute_dtype)

    if shape.kind == "train":
        k = flcfg.clients_per_round
        b = shape.global_batch // k
        seq = shape.seq
        if cfg.is_encdec:
            dlen = min(seq, AUDIO_DEC_LEN)
            batch = {
                "tokens": _sds((k, b, dlen), jnp.int32),
                "labels": _sds((k, b, dlen), jnp.int32),
                "enc_inputs": _sds((k, b, seq, cfg.frontend_dim), cdt),
            }
        elif cfg.family == "vlm":
            batch = {
                "tokens": _sds((k, b, seq), jnp.int32),
                "labels": _sds((k, b, seq), jnp.int32),
                "embeddings": _sds((k, b, VLM_PATCHES, cfg.frontend_dim), cdt),
            }
        else:
            batch = {
                "tokens": _sds((k, b, seq), jnp.int32),
                "labels": _sds((k, b, seq), jnp.int32),
            }
        umap = UnitMap.build(pstruct)
        loss_fn = functools.partial(_lm_loss, cfg)
        round_fn = build_round_scan(loss_fn, umap, flcfg)
        args = (pstruct, batch, _sds((k,), jnp.float32),
                _sds((2,), jnp.uint32))
        return Program(round_fn, args, ("params", "batch", "scalar", "scalar"),
                       flcfg)

    if shape.kind == "prefill":
        b, seq = shape.global_batch, shape.seq
        kwargs_struct = {}
        if cfg.is_encdec:
            tokens = _sds((b, min(seq, AUDIO_DEC_LEN)), jnp.int32)
            kwargs_struct["enc_inputs"] = _sds((b, seq, cfg.frontend_dim), cdt)
        elif cfg.family == "vlm":
            tokens = _sds((b, seq), jnp.int32)
            kwargs_struct["embeddings"] = _sds((b, VLM_PATCHES,
                                                cfg.frontend_dim), cdt)
        else:
            tokens = _sds((b, seq), jnp.int32)

        if cfg.is_encdec:
            def fn(params, tokens, enc_inputs):
                return dec.prefill(params, cfg, tokens, enc_inputs=enc_inputs)
            args = (pstruct, tokens, kwargs_struct["enc_inputs"])
            kinds = ("params", "batch", "batch")
        elif cfg.family == "vlm":
            def fn(params, tokens, embeddings):
                return dec.prefill(params, cfg, tokens, embeddings=embeddings)
            args = (pstruct, tokens, kwargs_struct["embeddings"])
            kinds = ("params", "batch", "batch")
        else:
            def fn(params, tokens):
                return dec.prefill(params, cfg, tokens)
            args = (pstruct, tokens)
            kinds = ("params", "batch")
        return Program(fn, args, kinds)

    # decode
    b, seq = shape.global_batch, shape.seq
    enc_len = AUDIO_DEC_CROSS if cfg.is_encdec else 0
    cache_struct = jax.eval_shape(
        lambda: dec.init_cache(cfg, b, seq, enc_len=enc_len))
    tokens = _sds((b, 1), jnp.int32)

    def fn(params, tokens, cache):
        return dec.decode_step(params, cfg, tokens, cache)

    return Program(fn, (pstruct, tokens, cache_struct),
                   ("params", "batch", "cache"))


def _lm_loss(cfg: ModelConfig, params, batch):
    return tf.lm_loss(params, cfg, batch)
