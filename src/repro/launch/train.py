"""FL training launcher.

    PYTHONPATH=src python -m repro.launch.train --task cifar \
        --algo fedldf --rounds 100 [--paper-scale] [--ckpt out/global.npz]
    PYTHONPATH=src python -m repro.launch.train --task lm \
        --arch qwen3-1.7b --reduced --algo fedldf --rounds 20

The cifar task is the paper's own experiment (§III-A); the lm task runs
FedLDF over any assigned architecture (reduced variants are CPU-friendly;
full-scale runs are what the dry-run lowers for the production mesh).
"""
from __future__ import annotations

import argparse
import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.checkpoint import save_pytree
from repro.configs import ARCH_IDS, get_config, vgg9_fl
from repro.data import (FederatedData, dirichlet_partition, iid_partition,
                        lm_federated, make_image_dataset, make_lm_dataset)
from repro.federated import ALGOS, FLConfig, run_training
from repro.models import cnn, transformer as tf


def train_cifar(args) -> None:
    if args.paper_scale:
        cfg = cnn.VGGConfig()
        fl = dataclasses.replace(vgg9_fl(args.algo), algo=args.algo)
        n_train, n_test = 50_000, 10_000
    else:
        cfg = cnn.VGGConfig().reduced()
        fl = FLConfig(algo=args.algo, num_clients=20, clients_per_round=10,
                      top_n=2, lr=args.lr, mode="vmap", batch_per_client=16)
        n_train, n_test = 4_000, 800
    train, test = make_image_dataset(num_train=n_train, num_test=n_test,
                                     seed=args.seed)
    splitter = (functools.partial(dirichlet_partition, alpha=1.0)
                if args.non_iid else iid_partition)
    parts = splitter(train.ys, fl.num_clients, seed=args.seed)
    data = FederatedData(train.xs, train.ys, parts)
    test_batch = {"images": jnp.asarray(test.xs),
                  "labels": jnp.asarray(test.ys)}
    loss_fn = functools.partial(lambda c, p, b: cnn.classify_loss(p, c, b),
                                cfg)
    eval_fn = jax.jit(lambda p: 1.0 - cnn.accuracy(p, cfg, test_batch))
    params = cnn.init_params(jax.random.PRNGKey(args.seed), cfg)
    params, log = run_training(params, loss_fn, data, fl, rounds=args.rounds,
                               eval_fn=eval_fn, eval_every=args.eval_every,
                               seed=args.seed, verbose=True)
    print("comm summary:", log.meter.summary())
    if args.ckpt:
        save_pytree(args.ckpt, params)
        print("saved global model to", args.ckpt)


def train_lm(args) -> None:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), param_dtype="float32",
                                  compute_dtype="float32")
    toks, domains = make_lm_dataset(num_sequences=512, seq_len=args.seq_len,
                                    vocab=cfg.vocab_size, seed=args.seed)
    data = lm_federated(toks, domains, num_clients=8)
    fl = FLConfig(algo=args.algo, num_clients=8, clients_per_round=4,
                  top_n=2, lr=args.lr, mode=args.mode, batch_per_client=4)
    loss_fn = functools.partial(lambda c, p, b: tf.lm_loss(p, c, b), cfg)
    params = tf.init_params(jax.random.PRNGKey(args.seed), cfg)
    params, log = run_training(params, loss_fn, data, fl, rounds=args.rounds,
                               seed=args.seed, verbose=True)
    print("comm summary:", log.meter.summary())
    if args.ckpt:
        save_pytree(args.ckpt, params)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=("cifar", "lm"), default="cifar")
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--algo", choices=ALGOS, default="fedldf")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--lr", type=float, default=0.08)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--mode", choices=("vmap", "scan"), default="scan")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()
    (train_cifar if args.task == "cifar" else train_lm)(args)


if __name__ == "__main__":
    main()
