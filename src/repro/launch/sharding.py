"""Auto-sharding policy (divisibility-driven, Megatron/FSDP-style defaults).

For every parameter/cache leaf we assign:
- the largest divisible non-leading dim -> 'model' (tensor parallel),
- the next largest divisible dim       -> the data/FSDP axis product
  ('data', or ('pod','data') multi-pod),
- everything else replicated.

Leaves under stacked top-level keys (blocks/enc_blocks) skip their leading
depth dim (it is scanned, never sharded). 1-D leaves (norm scales, biases)
are replicated. When a dim does not divide the axis size the policy falls
back rather than failing — this is what lets 25-head/28-head architectures
lower cleanly with MLP-only tensor parallelism (DESIGN.md §5).

``overrides`` allows per-path-regex PartitionSpec pinning — the hillclimb
lever used in §Perf.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes

Pytree = Any

STACKED_TOPKEYS = ("blocks", "enc_blocks", "dec_blocks")


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def auto_spec(shape: tuple[int, ...], mesh: Mesh, *,
              skip_leading: bool = False,
              model_axis: str = "model") -> P:
    """Generic two-level sharding of one array shape."""
    daxes = data_axes(mesh)
    daxis = daxes if len(daxes) > 1 else daxes[0]
    start = 1 if skip_leading else 0
    dims = list(range(start, len(shape)))
    spec: list = [None] * len(shape)

    def pick(axis, exclude: set[int]) -> Optional[int]:
        size = _axis_size(mesh, axis)
        cands = [d for d in dims if d not in exclude
                 and shape[d] >= size and shape[d] % size == 0]
        if not cands:
            return None
        return max(cands, key=lambda d: (shape[d], d))

    dm = pick(model_axis, set())
    if dm is not None:
        spec[dm] = model_axis
    dd = pick(daxis, {dm} if dm is not None else set())
    if dd is not None:
        spec[dd] = daxis
    return P(*spec)


def _iter_paths(tree: Pytree, prefix: str = ""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _iter_paths(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _iter_paths(v, f"{prefix}#{i}/")
    else:
        yield prefix.rstrip("/"), tree


def param_specs(params_shape: Pytree, mesh: Mesh,
                overrides: Optional[dict[str, P]] = None) -> Pytree:
    """PartitionSpec pytree for a parameter (or cache) shape tree.

    ``params_shape`` leaves: ShapeDtypeStruct or arrays.
    ``overrides``: {path-regex: PartitionSpec} applied first-match.
    """
    overrides = overrides or {}

    def assign(path: str, leaf) -> P:
        for pat, spec in overrides.items():
            if re.search(pat, path):
                return spec
        shape = leaf.shape
        if len(shape) <= 1:
            return P()
        top = path.split("/", 1)[0]
        skip = top in STACKED_TOPKEYS
        if len(shape) - (1 if skip else 0) < 1:
            return P()
        return auto_spec(shape, mesh, skip_leading=skip)

    flat = dict(_iter_paths(params_shape))
    specs = {path: assign(path, leaf) for path, leaf in flat.items()}

    def rebuild(tree: Pytree, prefix: str = "") -> Pytree:
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = type(tree)
            return t(rebuild(v, f"{prefix}#{i}/") for i, v in enumerate(tree))
        return specs[prefix.rstrip("/")]

    return rebuild(params_shape)


def to_named(spec_tree: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_shape: Pytree, mesh: Mesh, *,
                client_leading: bool = False) -> Pytree:
    """Shard the batch dim over the data axes.

    Leaves: (K, b, ...) when client_leading (FL round batch; the per-client
    batch dim b is sharded) or (b, ...) otherwise. Falls back to replication
    when b does not divide the axis product (e.g. long_500k's batch=1).
    """
    daxes = data_axes(mesh)
    daxis = daxes if len(daxes) > 1 else daxes[0]
    dsize = _axis_size(mesh, daxis)
    bdim = 1 if client_leading else 0

    def assign(leaf) -> P:
        shape = leaf.shape
        if len(shape) <= bdim or shape[bdim] % dsize or shape[bdim] < dsize:
            return P()
        spec: list = [None] * len(shape)
        spec[bdim] = daxis
        return P(*spec)

    return jax.tree.map(assign, batch_shape)
