"""Auto-sharding policy (divisibility-driven, Megatron/FSDP-style defaults).

For every parameter/cache leaf we assign:
- the largest divisible non-leading dim -> 'model' (tensor parallel),
- the next largest divisible dim       -> the data/FSDP axis product
  ('data', or ('pod','data') multi-pod),
- everything else replicated.

Leaves under stacked top-level keys (blocks/enc_blocks) skip their leading
depth dim (it is scanned, never sharded). 1-D leaves (norm scales, biases)
are replicated. When a dim does not divide the axis size the policy falls
back rather than failing — this is what lets 25-head/28-head architectures
lower cleanly with MLP-only tensor parallelism (DESIGN.md §5).

``overrides`` allows per-path-regex PartitionSpec pinning — the hillclimb
lever used in §Perf.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes

Pytree = Any

STACKED_TOPKEYS = ("blocks", "enc_blocks", "dec_blocks")


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def auto_spec(shape: tuple[int, ...], mesh: Mesh, *,
              skip_leading: bool = False,
              model_axis: str = "model",
              model_only: bool = False) -> P:
    """Generic two-level sharding of one array shape.

    ``model_only=True`` assigns the 'model' (tensor/FSDP) axis only and
    leaves every other dim replicated — the FL round engine uses this so a
    ('clients', 'model') mesh never shards parameter leaves over 'clients'
    (that axis carries stacked *clients*, not parameter blocks).
    """
    start = 1 if skip_leading else 0
    dims = list(range(start, len(shape)))
    spec: list = [None] * len(shape)

    def pick(axis, exclude: set[int]) -> Optional[int]:
        size = _axis_size(mesh, axis)
        cands = [d for d in dims if d not in exclude
                 and shape[d] >= size and shape[d] % size == 0]
        if not cands:
            return None
        return max(cands, key=lambda d: (shape[d], d))

    dm = pick(model_axis, set())
    if dm is not None:
        spec[dm] = model_axis
    if not model_only:
        daxes = data_axes(mesh)
        daxis = daxes if len(daxes) > 1 else daxes[0]
        dd = pick(daxis, {dm} if dm is not None else set())
        if dd is not None:
            spec[dd] = daxis
    return P(*spec)


def _iter_paths(tree: Pytree, prefix: str = ""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _iter_paths(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _iter_paths(v, f"{prefix}#{i}/")
    else:
        yield prefix.rstrip("/"), tree


def param_specs(params_shape: Pytree, mesh: Mesh,
                overrides: Optional[dict[str, P]] = None,
                model_only: bool = False,
                stacked_keys: tuple[str, ...] = STACKED_TOPKEYS) -> Pytree:
    """PartitionSpec pytree for a parameter (or cache) shape tree.

    ``params_shape`` leaves: ShapeDtypeStruct or arrays.
    ``overrides``: {path-regex: PartitionSpec} applied first-match.
    ``model_only``: see :func:`auto_spec` — 'model'-axis shards only.
    ``stacked_keys``: top-level keys whose leading dim is a stacked depth
    (never sharded); callers whose depth dim doubles as a *unit* axis (the
    FL engine) must list every such key or the unit bookkeeping breaks.
    """
    overrides = overrides or {}

    def assign(path: str, leaf) -> P:
        for pat, spec in overrides.items():
            if re.search(pat, path):
                return spec
        shape = leaf.shape
        if len(shape) <= 1:
            return P()
        top = path.split("/", 1)[0]
        skip = top in stacked_keys
        if len(shape) - (1 if skip else 0) < 1:
            return P()
        return auto_spec(shape, mesh, skip_leading=skip,
                         model_only=model_only)

    flat = dict(_iter_paths(params_shape))
    specs = {path: assign(path, leaf) for path, leaf in flat.items()}

    def rebuild(tree: Pytree, prefix: str = "") -> Pytree:
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = type(tree)
            return t(rebuild(v, f"{prefix}#{i}/") for i, v in enumerate(tree))
        return specs[prefix.rstrip("/")]

    return rebuild(params_shape)


def to_named(spec_tree: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------
# FL round engine ('clients' × 'model' mesh) — FSDP-style param policy and
# the shard_map-side reassembly/slicing that goes with it.
# ----------------------------------------------------------------------
def fl_param_specs(params_shape: Pytree, mesh: Mesh,
                   model_axis: str = "model") -> Pytree:
    """Model-axis-only PartitionSpecs for the federated round engine.

    Every parameter leaf gets its largest divisible dim (skipping the
    stacked depth dim for ``STACKED_TOPKEYS`` subtrees) assigned to the
    mesh's 'model' axis and everything else replicated — FSDP-style 1/M
    per-device shards with no 'clients'-axis factor (that axis carries
    stacked clients, never parameter blocks). On a mesh without a 'model'
    axis (or with ``model=1``) the whole tree is replicated (``P()``),
    which keeps 1-D client meshes byte-identical to the pre-model-axis
    engine. Indivisible leaves fall back to replication per ``auto_spec``.
    """
    names = getattr(mesh, "axis_names", ())
    if model_axis not in names or int(mesh.shape[model_axis]) <= 1:
        return jax.tree.map(lambda _: P(), params_shape)
    # the FL engine's unit bookkeeping (core/units.DEFAULT_STACKED_KEYS)
    # treats these leading depth dims as the *unit* axis — sharding one
    # would break the per-unit aggregation epilogue on 1/M slices, so they
    # must all be skip_leading here ('experts' is stacked for units but
    # not in the dry-run policy's STACKED_TOPKEYS).
    from repro.core.units import DEFAULT_STACKED_KEYS
    return param_specs(params_shape, mesh, model_only=True,
                       stacked_keys=tuple(set(STACKED_TOPKEYS)
                                          | set(DEFAULT_STACKED_KEYS)))


def residual_store_specs(params_shape: Pytree, mesh: Mesh) -> Pytree:
    """PartitionSpecs for an ``(N, ...)`` per-client store (EF residuals,
    control variates, any strategy client-state entry): the client-id axis
    is replicated (any client can be sampled onto any device), while each
    leaf's trailing dims carry the same 'model'-axis sharding as the
    corresponding parameter leaf (:func:`fl_param_specs`). All-replicated
    on meshes without a 'model' axis."""
    pspecs = fl_param_specs(params_shape, mesh)
    return jax.tree.map(lambda s: P(None, *s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def init_residual_store(params: Pytree, num_clients: int,
                        mesh: Optional[Mesh] = None) -> Pytree:
    """Per-client error-feedback residual store: every leaf gets a leading
    ``(N,)`` client axis, zero-initialised **in the leaf's own dtype** (a
    hard-coded float32 store silently upcast EF arithmetic — and doubled
    the store's memory — for bf16/fp16 models). Rows for the round's
    participants are gathered before the round and scattered back after —
    residuals belong to *clients*, not to sampling slots. At N × model
    size this store is the first memory cliff; under a 2-D
    ('clients', 'model') mesh pass ``mesh`` so it is held 'model'-axis
    sharded (:func:`residual_store_specs`), 1/M per device — and *created*
    sharded: the zeros are jitted with sharded out_shardings, so the full
    replicated store never materialises on any single device (allocating
    it first and resharding after would reintroduce, at init time, exactly
    the cliff the sharding removes)."""
    import jax.numpy as jnp

    def build():
        return jax.tree.map(
            lambda l: jnp.zeros((num_clients,) + l.shape, l.dtype), params)

    if mesh is None:
        return build()
    shardings = to_named(residual_store_specs(params, mesh), mesh)
    return jax.jit(build, out_shardings=shardings)()


def _model_dim(spec: P, axis_name: str) -> Optional[int]:
    for i, s in enumerate(spec):
        if s == axis_name:
            return i
    return None


def tree_all_gather(tree: Pytree, spec_tree: Pytree,
                    axis_name: str = "model", offset: int = 0) -> Pytree:
    """Reassemble full leaves from per-device 'model'-axis shards.

    Only callable inside ``shard_map``. ``spec_tree`` is the
    :func:`fl_param_specs` tree of the *unprefixed* leaves; ``offset``
    shifts every spec dim right (e.g. ``offset=1`` for error-feedback rows
    whose leaves carry a leading client axis the spec does not mention).
    Leaves whose spec has no 'model' entry are already full — returned
    untouched, so a replicated tree makes this a no-op.
    """
    def gather(x, spec):
        d = _model_dim(spec, axis_name)
        if d is None:
            return x
        return jax.lax.all_gather(x, axis_name, axis=d + offset, tiled=True)

    return jax.tree.map(gather, tree, spec_tree)


def tree_shard_slice(tree: Pytree, spec_tree: Pytree, axis_size: int,
                     axis_name: str = "model", offset: int = 0) -> Pytree:
    """Slice full leaves down to this device's 'model'-axis shard — the
    inverse of :func:`tree_all_gather`, same calling convention. Exact
    (pure data movement): gather-then-slice round-trips bit-identically.
    """
    def shard(x, spec):
        d = _model_dim(spec, axis_name)
        if d is None:
            return x
        dim = d + offset
        size = x.shape[dim] // axis_size
        start = jax.lax.axis_index(axis_name) * size
        return jax.lax.dynamic_slice_in_dim(x, start, size, axis=dim)

    return jax.tree.map(shard, tree, spec_tree)


def batch_specs(batch_shape: Pytree, mesh: Mesh, *,
                client_leading: bool = False) -> Pytree:
    """Shard the batch dim over the data axes.

    Leaves: (K, b, ...) when client_leading (FL round batch; the per-client
    batch dim b is sharded) or (b, ...) otherwise. Falls back to replication
    when b does not divide the axis product (e.g. long_500k's batch=1).
    """
    daxes = data_axes(mesh)
    daxis = daxes if len(daxes) > 1 else daxes[0]
    dsize = _axis_size(mesh, daxis)
    bdim = 1 if client_leading else 0

    def assign(leaf) -> P:
        shape = leaf.shape
        if len(shape) <= bdim or shape[bdim] % dsize or shape[bdim] < dsize:
            return P()
        spec: list = [None] * len(shape)
        spec[bdim] = daxis
        return P(*spec)

    return jax.tree.map(assign, batch_shape)
