"""Verbosity-controlled progress sink for the round drivers.

Replaces the drivers' hardcoded ``print(f"round {t:4d} ...")`` reporting
with a selectable mode:

- ``quiet``      — nothing (the default when ``verbose=False``);
- ``human``      — the classic one-line-per-report format, byte-identical
  to the old prints (so eyeballs and grep habits keep working);
- ``structured`` — one JSON object per report line, machine-parseable
  (mirrors the ledger's field names, minus the heavyweight taps).

Drivers resolve the mode with :meth:`ProgressSink.for_run`: an explicit
``TelemetryConfig.verbosity`` wins; ``"auto"`` (or no telemetry at all)
follows the driver's legacy ``verbose`` flag.
"""
from __future__ import annotations

import json
import sys
from typing import Optional


class ProgressSink:
    def __init__(self, mode: str = "quiet", stream=None):
        assert mode in ("quiet", "human", "structured"), mode
        self.mode = mode
        self.stream = stream if stream is not None else sys.stdout

    @classmethod
    def for_run(cls, telemetry, verbose: bool, stream=None) -> "ProgressSink":
        """Resolve the mode from (TelemetryConfig | None, verbose flag)."""
        mode = "human" if verbose else "quiet"
        if telemetry is not None and telemetry.verbosity != "auto":
            mode = telemetry.verbosity
        return cls(mode, stream=stream)

    @property
    def enabled(self) -> bool:
        return self.mode != "quiet"

    # ------------------------------------------------------------------
    def round(self, t: int, loss: float,
              test_error: Optional[float] = None,
              uplink_bytes: Optional[float] = None) -> None:
        """One progress report. ``test_error`` set => the eval-line format
        (always reported); plain rounds are reported at the driver's own
        cadence (every 10th round, matching the legacy prints)."""
        if self.mode == "quiet":
            return
        if self.mode == "structured":
            rec = {"kind": "progress", "round": int(t), "loss": float(loss)}
            if test_error is not None:
                rec["test_error"] = float(test_error)
            if uplink_bytes is not None:
                rec["uplink_bytes"] = float(uplink_bytes)
            print(json.dumps(rec), file=self.stream)
            return
        if test_error is not None:
            print(f"round {t:4d} loss {loss:.4f} "
                  f"test_err {test_error:.4f} "
                  f"uplink {uplink_bytes / 1e6:.1f}MB", file=self.stream)
        else:
            print(f"round {t:4d} loss {loss:.4f}", file=self.stream)
