"""Profiling hooks: trace windows, retrace counters, system sampling.

Three independent facilities the round drivers wire in when telemetry is
configured:

- :class:`ProfileWindow` — a ``jax.profiler`` trace over a configurable
  absolute-round range (``TelemetryConfig.profile_rounds``). The host
  driver opens/closes it exactly at the window bounds; the scan driver
  snaps to eval-block boundaries (a jitted ``lax.scan`` cannot be split
  mid-block). Profiler failures degrade to a one-time warning — tracing
  is best-effort observability, never a correctness dependency.
- **engine-cache retrace counters** — ``repro.federated.server``'s
  compiled-callable cache reports every build/hit here, so "did this
  config recompile?" is a queryable fact instead of a wall-clock guess:
  :func:`engine_cache_stats` after two identical ``run_training_scan``
  calls must show zero new builds (regression-tested).
- :func:`device_memory_peak` / wall-clock sampling — best-effort
  ``memory_stats()`` peak bytes for the ledger's per-round system fields
  (returns ``None`` on backends that don't report, e.g. CPU).
"""
from __future__ import annotations

import collections
import sys
from typing import Optional

# ----------------------------------------------------------------------
# Engine-cache retrace counters
# ----------------------------------------------------------------------
_CACHE_EVENTS: "collections.Counter[str]" = collections.Counter()


def note_engine_cache(kind: str, *, hit: bool) -> None:
    """Called by the round-engine compiled-callable cache on every lookup:
    ``kind`` is the cache's entry kind ('round' for the host driver's
    jitted round, 'block' for the scan driver's block fn)."""
    _CACHE_EVENTS[f"{kind}_{'hits' if hit else 'builds'}"] += 1


def engine_cache_stats() -> dict:
    """Cumulative build/hit counts per engine kind since the last reset.
    ``<kind>_builds`` counts fresh traces+compiles (a nonzero delta across
    two identical driver calls means the compiled-callable cache missed —
    the retrace regression the telemetry subsystem pins)."""
    return dict(_CACHE_EVENTS)


def reset_engine_cache_stats() -> None:
    _CACHE_EVENTS.clear()


# ----------------------------------------------------------------------
# System sampling
# ----------------------------------------------------------------------
def device_memory_peak() -> Optional[int]:
    """Peak device-memory bytes of device 0, or None when the backend
    does not report memory stats (CPU) or the query fails."""
    try:
        import jax
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        return None
    if not stats:
        return None
    peak = stats.get("peak_bytes_in_use") or stats.get("bytes_in_use")
    return int(peak) if peak else None


# ----------------------------------------------------------------------
# jax.profiler trace windows
# ----------------------------------------------------------------------
class ProfileWindow:
    """Start/stop a ``jax.profiler`` trace over a round range.

    Host driver: ``round_begin(t)`` / ``round_end(t)`` bracket each round
    — the trace starts when ``t`` hits the window's first round and stops
    after its last. Scan driver: ``block_begin(t0, t1)`` /
    ``block_end(t1)`` bracket each eval block with absolute round bounds
    ``[t0, t1)`` — the trace covers every block overlapping the window
    (the window is snapped outward to block boundaries).
    """

    def __init__(self, rounds: Optional[tuple[int, int]], trace_dir: str):
        self.lo, self.hi = rounds if rounds is not None else (None, None)
        self.trace_dir = trace_dir
        self.active = False
        self._warned = False

    @classmethod
    def from_config(cls, telemetry) -> "ProfileWindow":
        if telemetry is None:
            return cls(None, "")
        return cls(telemetry.profile_rounds, telemetry.profile_dir)

    # ------------------------------------------------------------------
    def _start(self) -> None:
        try:
            import jax
            jax.profiler.start_trace(self.trace_dir)
            self.active = True
        except Exception as e:   # profiling is best-effort
            if not self._warned:
                print(f"telemetry: profiler trace unavailable ({e})",
                      file=sys.stderr)
                self._warned = True
            self.lo = None       # don't retry every round

    def _stop(self) -> None:
        if not self.active:
            return
        try:
            import jax
            jax.profiler.stop_trace()
        except Exception as e:
            if not self._warned:
                print(f"telemetry: profiler stop failed ({e})",
                      file=sys.stderr)
                self._warned = True
        self.active = False

    # ---- host driver: exact round bounds ----
    def round_begin(self, t: int) -> None:
        if self.lo is not None and not self.active and self.lo <= t <= self.hi:
            self._start()

    def round_end(self, t: int) -> None:
        if self.active and t >= self.hi:
            self._stop()

    # ---- scan driver: eval-block granularity ----
    def block_begin(self, t0: int, t1: int) -> None:
        """Block covers absolute rounds [t0, t1)."""
        if self.lo is not None and not self.active and \
                t0 <= self.hi and t1 > self.lo:
            self._start()

    def block_end(self, t1: int) -> None:
        if self.active and t1 > self.hi:
            self._stop()

    def close(self) -> None:
        """Stop an open trace at end of run (window past the last round)."""
        self._stop()
