"""Round telemetry subsystem: in-jit taps, JSONL ledger, profiling hooks.

Light imports only — the ledger readers and :class:`ProgressSink` are
numpy/stdlib-only so consumers (``launch/monitor.py``, report tooling)
can import this package without pulling in JAX. The jit-side helpers
live in :mod:`repro.telemetry.taps` and the profiler glue in
:mod:`repro.telemetry.profiling`; the round drivers import those
directly.
"""
from repro.telemetry.config import TelemetryConfig, VERBOSITY_MODES
from repro.telemetry.ledger import (
    LEDGER_SCHEMA,
    RoundLedger,
    read_ledger,
    split_runs,
)
from repro.telemetry.sink import ProgressSink

__all__ = [
    "TelemetryConfig",
    "VERBOSITY_MODES",
    "LEDGER_SCHEMA",
    "RoundLedger",
    "read_ledger",
    "split_runs",
    "ProgressSink",
]
