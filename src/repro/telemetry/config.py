"""Telemetry configuration: what the round drivers tap, log, and profile.

``FLConfig(telemetry=TelemetryConfig(...))`` switches the round engines
from their default fire-and-forget metrics into structured observability:

- **in-jit metric taps** (``taps=True``) widen the per-round metrics dict
  with per-layer divergence vectors (the Eq. 4 inputs), per-layer
  selection counts, per-client selection masks (``full_selection``), and
  strategy-state summaries (FedLAMA's interval/ttl vectors, EF residual
  norms) — all collected on device through the scan carry outputs /
  shard_map out_specs, with **no host syncs mid-scan**;
- a **JSONL event ledger** (``ledger_path``): one schema-versioned record
  per round (plus run-header and eval records), written incrementally by
  both drivers and opened in append mode, so a run resumed via
  ``start_round``/``server_state`` continues a contiguous ledger;
- **profiling hooks**: a ``jax.profiler`` trace window over a round range
  (``profile_rounds``), per-round wall-clock and peak-device-memory
  sampling (``sample_system``), and the engine-cache retrace counters in
  :mod:`repro.telemetry.profiling`;
- a **verbosity-controlled progress sink** (``verbosity``) replacing the
  drivers' hardcoded ``print`` reporting: ``quiet`` / ``human`` (the
  classic one-line-per-eval format) / ``structured`` (JSON lines).

``telemetry=None`` (the FLConfig default) is the zero-cost path: the
compiled rounds are unchanged, no extra scan-carry leaves exist, and
fixed-seed trajectories are bit-identical to a build without this module.

The config must stay hashable (``FLConfig`` is a jit-cache key);
:meth:`trace_key` strips the host-only fields so e.g. two runs differing
only in ``ledger_path`` share one compiled round.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

VERBOSITY_MODES = ("auto", "quiet", "human", "structured")


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Per-run observability knobs (see module docstring)."""

    # ---- in-jit taps (trace-relevant: change the compiled round) ----
    taps: bool = True            # per-layer divergence/selection/state taps
    full_selection: bool = True  # include the full (K, U) selection mask
    # ---- host-side event ledger ----
    ledger_path: Optional[str] = None   # JSONL sink; None = no ledger
    run_id: str = ""                    # free-form run label in the header
    # ---- progress sink ----
    # "auto" follows the driver's ``verbose`` flag (human when verbose);
    # "quiet"/"human"/"structured" force a mode regardless of ``verbose``.
    verbosity: str = "auto"
    # ---- profiling hooks ----
    # (start, stop) absolute round indices for a jax.profiler trace window
    # (inclusive; the scan driver snaps the window to eval-block bounds).
    profile_rounds: Optional[tuple[int, int]] = None
    profile_dir: str = "telemetry_trace"
    # per-round wall-clock + peak-device-memory sampling (ledger fields;
    # the scan driver amortises one sample per eval block)
    sample_system: bool = True

    def __post_init__(self):
        if self.verbosity not in VERBOSITY_MODES:
            raise ValueError(
                f"verbosity must be one of {VERBOSITY_MODES}, "
                f"got {self.verbosity!r}")
        if self.profile_rounds is not None:
            lo, hi = self.profile_rounds
            if lo > hi or lo < 0:
                raise ValueError(
                    f"profile_rounds must be (start <= stop), 0-based "
                    f"absolute round indices; got {self.profile_rounds}")
            # tuples survive hashing; anything else (lists) would break the
            # jit-cache key, so normalise here
            object.__setattr__(self, "profile_rounds", (int(lo), int(hi)))

    # ------------------------------------------------------------------
    def trace_key(self) -> "TelemetryConfig":
        """The trace-relevant subset: fields that change the *compiled*
        round/block functions. Host-only fields (ledger path, run id,
        verbosity, profiler window, system sampling) are reset so the
        engine jit-cache is keyed only on what actually retraces."""
        return TelemetryConfig(taps=self.taps,
                               full_selection=self.full_selection)

    @property
    def wants_ledger(self) -> bool:
        return bool(self.ledger_path)
