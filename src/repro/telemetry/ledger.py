"""Schema-versioned JSONL event ledger for FL training runs.

One line per event, three kinds:

- ``run``   — a run-segment header: schema version, free-form ``run_id``,
  algorithm/driver/config metadata, the layer-unit names (so consumers can
  label per-layer vectors without rebuilding the model), and the absolute
  ``start_round``. Written once per driver invocation.
- ``round`` — one record per training round: absolute round index, loss,
  the full per-round communication profile (realised uplink/downlink
  bytes), cumulative uplink, the in-jit telemetry taps (per-layer
  divergence vectors, selection counts, strategy-state summaries), the
  optional full per-client selection mask, and host-side samples
  (wall-clock seconds, peak device memory).
- ``eval``  — one record per evaluation point: round, test error,
  cumulative uplink bytes at that point.

The file is opened in **append** mode and flushed per event, so a crashed
run keeps everything written so far and a run resumed with
``start_round``/``server_state`` (see ``repro.checkpoint``) continues the
same file with contiguous round indices — the resumed ledger's ``round``
records are identical in indices to an uninterrupted run's (tested).
Multiple runs may share one file (e.g. an algorithm sweep); consumers
group records by the preceding ``run`` header via :func:`split_runs`.

Readers (:func:`read_ledger`, :func:`split_runs`) are numpy/stdlib-only so
``launch/monitor.py`` and report tooling work without JAX.

Schema changes bump :data:`LEDGER_SCHEMA`; readers skip records from a
*newer* major schema with a warning instead of crashing, and every record
carries its own version so mixed files stay parseable.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Any, Optional

import numpy as np

LEDGER_SCHEMA = 1


def _jsonable(v: Any) -> Any:
    """Device arrays / numpy scalars -> plain JSON types."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    arr = np.asarray(v)
    if arr.ndim == 0:
        return arr.item()
    return arr.tolist()


class RoundLedger:
    """Incremental JSONL writer (append mode, one flush per event)."""

    def __init__(self, path: str, meta: Optional[dict] = None):
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self.path = path
        self._f = open(path, "a")
        if meta is not None:
            self._write({"kind": "run", "time_unix": time.time(),
                         **_jsonable(meta)})

    # ------------------------------------------------------------------
    def _write(self, record: dict) -> None:
        record = {"schema": LEDGER_SCHEMA, **record}
        self._f.write(json.dumps(record, allow_nan=True) + "\n")
        self._f.flush()

    def round(self, t: int, loss, comm: dict, uplink_cum_bytes,
              taps: Optional[dict] = None, selection=None,
              wall_s=None, mem_peak_bytes=None) -> None:
        """One training-round record. Field set is driver-independent:
        both ``run_training`` and ``run_training_scan`` emit exactly these
        keys (schema-equality is pinned by tests/test_telemetry.py)."""
        rec = {"kind": "round", "round": int(t),
               "loss": float(np.asarray(loss)),
               "comm": _jsonable(comm),
               "uplink_cum_bytes": float(np.asarray(uplink_cum_bytes)),
               "taps": _jsonable(taps) if taps is not None else None,
               "wall_s": (float(wall_s) if wall_s is not None else None),
               "mem_peak_bytes": (int(mem_peak_bytes)
                                  if mem_peak_bytes is not None else None)}
        if selection is not None:
            rec["selection"] = np.asarray(selection).astype(int).tolist()
        self._write(rec)

    def eval(self, t: int, test_error, uplink_cum_bytes) -> None:
        self._write({"kind": "eval", "round": int(t),
                     "test_error": float(np.asarray(test_error)),
                     "uplink_cum_bytes": float(np.asarray(uplink_cum_bytes))})

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ----------------------------------------------------------------------
# Readers (stdlib + numpy only — no JAX)
# ----------------------------------------------------------------------
def read_ledger(path: str) -> list[dict]:
    """Parse a JSONL ledger into a record list, skipping blank/corrupt
    lines (a crashed writer may leave a torn final line) and records from
    a newer schema (with one warning each)."""
    records = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                print(f"ledger: skipping corrupt line {i + 1} of {path}",
                      file=sys.stderr)
                continue
            if rec.get("schema", 0) > LEDGER_SCHEMA:
                print(f"ledger: skipping line {i + 1} of {path} "
                      f"(schema {rec.get('schema')} > {LEDGER_SCHEMA}; "
                      "upgrade the reader)", file=sys.stderr)
                continue
            records.append(rec)
    return records


def split_runs(records: list[dict]) -> list[dict]:
    """Group a record list into run segments: each ``run`` header starts a
    segment that collects the following ``round``/``eval`` records.
    Headerless records (hand-rolled files) land in a segment with
    ``meta=None``."""
    runs: list[dict] = []

    def _fresh(meta):
        return {"meta": meta, "rounds": [], "evals": []}

    cur = None
    for rec in records:
        kind = rec.get("kind")
        if kind == "run":
            cur = _fresh(rec)
            runs.append(cur)
        elif kind in ("round", "eval"):
            if cur is None:
                cur = _fresh(None)
                runs.append(cur)
            cur["rounds" if kind == "round" else "evals"].append(rec)
    return runs
