"""In-jit tap collection: the engine side of the telemetry taps.

The strategy side is :meth:`FLStrategy.telemetry_taps` — a jit-safe hook
whose default derives per-layer selection counts, divergence statistics
(the Eq. 4 inputs), and summaries of the *global* state entries from the
hooks every strategy already implements. The helpers here add what only
the engines can see:

- :func:`client_sqsums` — squared-norm partials of the round's *client*
  state rows (e.g. the participants' error-feedback residuals). Under the
  mesh-sharded round the rows are device-local, so the engine computes
  these partials locally and rides them on the round's single fused
  ``psum`` (no extra rendezvous, no host sync); the unsharded engines sum
  the same quantity over all K rows directly, so the tapped value is
  driver-independent.
- :func:`collect` — assemble the final per-round tap dict: the strategy
  hook on replicated inputs (selection/divergence/global state) plus
  ``state_<name>_norm`` entries from the client-row partials.

Client-entry norms are sampled *after* the upload transform updated them
(the EF residual treatment) and before :meth:`FLStrategy.update_state`;
global-entry summaries reflect the post-``update_state`` value — i.e.
taps describe the state the next round will start from.

Everything here is traced under ``jax.jit`` — static structure, no host
callbacks.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def client_sqsums(client: dict) -> dict:
    """Per-entry sum of squares over every leaf of the round's client-state
    rows: ``{name: f32 scalar}``. Additive over the client axis, so the
    mesh engine can psum per-device partials into the global value."""
    out = {}
    for name, rows in client.items():
        parts = [jnp.sum(jnp.square(l.astype(jnp.float32)))
                 for l in jax.tree.leaves(rows)]
        out[name] = sum(parts, jnp.float32(0.0))
    return out


def collect(strategy, state: Optional[dict], selection, divs, umap,
            client_sq: Optional[dict] = None,
            extra: Optional[dict] = None) -> dict:
    """Build one round's tap dict (see module docstring).

    ``state`` is the round-local post-``update_state`` view (client rows
    included off-mesh). ``client_sq`` carries pre-reduced client partials
    when the caller already psum'd them (the mesh engine); ``None`` means
    compute them here from ``state['client']``. ``extra`` merges
    engine-side taps that no hook can see — e.g. the packed uplink's
    per-unit wire bytes and bit-width allocation (replicated values; keys
    must be static across rounds like every tap).
    """
    gview = None
    if state and state.get("global"):
        gview = {"global": state["global"]}
    taps = dict(strategy.telemetry_taps(gview, selection, divs, umap))
    if client_sq is None and state and state.get("client"):
        client_sq = client_sqsums(state["client"])
    if client_sq:
        for name, sq in client_sq.items():
            taps[f"state_{name}_norm"] = jnp.sqrt(sq)
    if extra:
        taps.update(extra)
    return taps
