"""Pytree checkpoint I/O (npz, path-flattened keys).

Simple, dependency-free persistence for server state between FL rounds and
for the serving examples. Keys are '/'-joined tree paths; structure is
reconstructed from the keys, so load does not need a template.
"""
from __future__ import annotations

import os
from typing import Any

import numpy as np

Pytree = Any
_SEP = "/"


def _flatten(tree: Pytree, prefix: str = "") -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}{_SEP}"))
    else:
        out[prefix.rstrip(_SEP)] = np.asarray(tree)
    return out


def save_pytree(path: str, tree: Pytree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)


def save_server_state(path: str, params: Pytree,
                      state: Pytree | None = None) -> None:
    """Persist an FL server snapshot: global params plus the strategy's
    cross-round state (``TrainLog.final_state`` — the EF residual store,
    FedLAMA's interval accumulators, any :meth:`FLStrategy.init_state`
    schema). Stateless runs (``state=None``) save params only; the
    round-trip is exact (same arrays back), so feeding the loaded pair
    into ``run_training*(start_round=..., server_state=...)`` continues a
    run bit-identically (regression-tested in tests/test_state_seam.py).
    """
    tree = {"params": params}
    if state is not None:
        tree["state"] = state
    save_pytree(path, tree)


def load_server_state(path: str) -> tuple[Pytree, Pytree | None]:
    """Inverse of :func:`save_server_state` → ``(params, state)`` with
    ``state=None`` when the snapshot was stateless."""
    tree = load_pytree(path)
    if "params" not in tree:
        raise ValueError(
            f"{path!r} is not a server-state snapshot (no 'params' root; "
            "was it written with save_pytree instead of save_server_state?)")
    return tree["params"], tree.get("state")


def load_pytree(path: str) -> Pytree:
    data = np.load(path, allow_pickle=False)
    root: dict = {}
    for key in data.files:
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = data[key]

    def delistify(node):
        if isinstance(node, dict):
            if node and all(k.startswith("#") for k in node):
                items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
                return tuple(delistify(v) for _, v in items)
            return {k: delistify(v) for k, v in node.items()}
        return node

    return delistify(root)
