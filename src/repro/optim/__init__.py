"""Optimizers (pure JAX, functional)."""
from repro.optim.opt import adamw, sgd

__all__ = ["sgd", "adamw"]
