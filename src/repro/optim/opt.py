"""SGD(+momentum) and AdamW as (init, update) pairs over pytrees.

The paper's ClientUpdate (Algorithm 1 line 14) is one plain SGD step; we keep
momentum/AdamW for the beyond-paper experiments (local adaptivity) and for
the serving-model pre-training example.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], tuple[Pytree, Pytree]]
    # update(grads, state, params) -> (new_params, new_state)


def sgd(lr: float, momentum: float = 0.0,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda l: jnp.zeros_like(l, dtype=jnp.float32),
                            params)

    def update(grads, state, params):
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype),
                grads, params)
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_params, ()
        new_state = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, new_state)
        return new_params, new_state

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda: jax.tree.map(
            lambda l: jnp.zeros_like(l, dtype=jnp.float32), params)
        return {"m": zeros(), "v": zeros(), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def step(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        return (jax.tree.map(step, params, m, v),
                {"m": m, "v": v, "t": t})

    return Optimizer(init, update)
