"""Pallas TPU kernels.

FedLDF hot spots:
- divergence.py : per-row Σ(a−b)² (Eq. 3 inner reduction), VMEM-tiled.
- aggregate.py  : fused acc += w[r]·x (Eq. 5 accumulation).
- uplink.py     : fused packed-uplink dequant + EF update + Eq. 5
                  accumulate over int8 wire buffers (core/wire.py).

Substrate hot spot (motivated by §Perf pairs A/E — XLA keeps flash
probabilities in HBM; the fused kernel keeps them in VMEM):
- flash_attention.py : GQA flash attention (causal/sliding-window).

- ref.py : pure-jnp oracles (ground truth + CPU fast path).
- ops.py : backend-dispatching wrappers used by repro.core.
"""
from repro.kernels import (aggregate, divergence, flash_attention, ops, ref,
                           uplink)

__all__ = ["aggregate", "divergence", "flash_attention", "ops", "ref",
           "uplink"]
