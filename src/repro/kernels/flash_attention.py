"""Pallas TPU flash attention (GQA, causal, sliding window).

Motivated directly by the §Perf findings (EXPERIMENTS.md pairs A/E): XLA
lowers the pure-JAX online-softmax scan with probability and carry tensors
in HBM — flash's whole point is keeping them in VMEM. This kernel is the
TPU-native fix: the (TQ, TK) score/probability tile lives in registers/VMEM
only; running max/denominator are (TQ, 1) blocks revisited across the KV
grid dimension (TPU grids execute sequentially minor-most-last, the same
reduction pattern as kernels/divergence.py).

Layout: q (BH, Sq, hd), k/v (BKV, Skv, hd) with BH = B·H, BKV = B·KV —
GQA needs no head-repeat: the kv BlockSpec index-maps bh → bh // group.
fp32 accumulation; bf16/f32 inputs.

Block shapes default to (TQ, TK) = (256, 512): q/k/v tiles + fp32
accumulator ≈ (256+2·512)·128·4 B ≈ 0.7 MB ≪ VMEM; hd is MXU-lane-aligned
(128) for every assigned architecture.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TQ = 256
DEFAULT_TK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  tq: int, tk: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # tile-level skip: fully-masked (causal/window) KV tiles do no work
    run = jnp.bool_(True)
    if causal:
        run &= (ki * tk) <= (qi * tq + tq - 1)
    if window > 0:
        run &= ((ki + 1) * tk - 1) > (qi * tq - window)

    @pl.when(run)
    def _tile():
        q = q_ref[0].astype(jnp.float32)                      # (TQ, hd)
        k = k_ref[0].astype(jnp.float32)                      # (TK, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (TQ, TK)

        q_pos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        ok = k_pos < kv_len                                   # pad mask
        if causal:
            ok &= k_pos <= q_pos
        if window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[0]                                     # (TQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        # guard fully-masked rows: exp(NEG_INF − NEG_INF) must be 0, not 1
        safe = m_new > NEG_INF / 2
        p = jnp.where(safe & ok, jnp.exp(s - m_new), 0.0)
        corr = jnp.where(safe & (m_prev > NEG_INF / 2),
                         jnp.exp(m_prev - m_new), 0.0)
        l_ref[...] = (l_ref[0] * corr + p.sum(axis=1, keepdims=True))[None]
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (TQ, hd)
        o_ref[...] = (o_ref[0] * corr + pv)[None]
        m_ref[...] = m_new[None]

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[...] = o_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "tq", "tk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    tq: int = DEFAULT_TQ, tk: int = DEFAULT_TK,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: (BH, Sq, hd); k, v: (BKV, Skv, hd), BH = BKV·group.

    Returns (BH, Sq, hd) in q.dtype. Sq/Skv are zero-padded to tile
    multiples internally (padded KV masked via kv_len). ``interpret=None``
    resolves via the backend check (compiled on TPU, interpret elsewhere).
    """
    if interpret is None:
        from repro.kernels import ops
        interpret = ops._interpret()
    bh, sq, hd = q.shape
    bkv, skv, _ = k.shape
    assert bh % bkv == 0, (bh, bkv)
    group = bh // bkv
    tq = min(tq, max(8, sq))
    tk = min(tk, max(128, skv))
    sq_p = pl.cdiv(sq, tq) * tq
    skv_p = pl.cdiv(skv, tk) * tk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0)))

    grid = (bh, sq_p // tq, skv_p // tk)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (hd ** 0.5), causal=causal,
        window=window, tq=tq, tk=tk, kv_len=skv)
    out, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, hd),
                         lambda b, i, j, group=group: (b // group, j, 0)),
            pl.BlockSpec((1, tk, hd),
                         lambda b, i, j, group=group: (b // group, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tq, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tq, 1), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq_p, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, sq_p, 1), jnp.float32),
            jax.ShapeDtypeStruct((bh, sq_p, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :].astype(q.dtype)


def ref_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Pure-jnp oracle, same GQA layout as the kernel."""
    bh, sq, hd = q.shape
    bkv, skv, _ = k.shape
    group = bh // bkv
    kr = jnp.repeat(k, group, axis=0)
    vr = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / (hd ** 0.5)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)
