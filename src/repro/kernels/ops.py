"""Dispatching wrappers for the FedLDF kernels.

On TPU the Pallas kernels run compiled; on CPU (this container) the pure-jnp
reference is both the oracle and the fast path (interpret-mode Pallas
executes the kernel body in Python and is only used for validation).

The kernel entry points (``sqdiff_rowsum``, ``masked_accumulate``,
``flash_attention``) default to ``interpret=None``, which resolves through
:func:`_interpret` here — so TPU callers get compiled Pallas without opting
in, and CPU callers get interpret mode.

Set ``REPRO_FORCE_PALLAS=1`` to route through the Pallas kernels in
interpret mode everywhere (used by tests/CI to exercise the kernel path).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import aggregate as _aggregate
from repro.kernels import divergence as _divergence
from repro.kernels import ref as _ref
from repro.kernels import uplink as _uplink


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS", "0") == "1":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def sqdiff_rowsum(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(R, C), (R, C) -> (R,) float32 per-row Σ(a−b)²."""
    if _use_pallas():
        return _divergence.sqdiff_rowsum(a, b, interpret=_interpret())
    return _ref.sqdiff_rowsum(a, b)


def masked_accumulate(acc: jnp.ndarray, x: jnp.ndarray,
                      w: jnp.ndarray) -> jnp.ndarray:
    """(R, C), (R, C), (R,) -> (R, C) float32: acc + w[:,None]*x."""
    if _use_pallas():
        return _aggregate.masked_accumulate(acc, x, w, interpret=_interpret())
    return _ref.masked_accumulate(acc, x, w)


def fused_uplink(levels: jnp.ndarray, scales: jnp.ndarray,
                 w: jnp.ndarray) -> jnp.ndarray:
    """(K,R,C) int levels, (K,R), (K,R) -> (R,C) f32 Eq. 5 numerator."""
    if _use_pallas():
        return _uplink.fused_uplink(levels, scales, w,
                                    interpret=_interpret())
    return _ref.fused_uplink(levels, scales, w)


def fused_uplink_ef(levels: jnp.ndarray, scales: jnp.ndarray,
                    w: jnp.ndarray, gate: jnp.ndarray, v: jnp.ndarray,
                    e_old: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused dequant + Eq. 5 numerator + EF residual update.
    -> (num (R,C), new_res (K,R,C)) f32."""
    if _use_pallas():
        return _uplink.fused_uplink_ef(levels, scales, w, gate, v, e_old,
                                       interpret=_interpret())
    return _ref.fused_uplink_ef(levels, scales, w, gate, v, e_old)
