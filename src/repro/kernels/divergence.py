"""Pallas TPU kernel: per-row sum of squared differences (Eq. 3 inner loop).

Layer divergence in FedLDF reduces K × (full model size) elements per round:
for every layer-unit row ``r``, ``out[r] = Σ_c (a[r,c] − b[r,c])²``. On TPU we
tile ``(Rb, Cb)`` blocks through VMEM and accumulate in float32 into an
``(Rb, 1)`` output block that is revisited across the column grid dimension
(TPU grids iterate sequentially, minor-most last, so read-modify-write of the
same output block across the ``j`` dimension is the standard reduction
pattern).

Block sizes default to (8, 2048): 8 sublanes × 2048 lanes = 64 KiB fp32 per
operand block — two operand blocks plus the accumulator fit comfortably in
the ~16 MiB VMEM budget, and both dims are (8, 128)-aligned for the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 8
DEFAULT_BLOCK_C = 2048


def _sqdiff_kernel(a_ref, b_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    d = a - b
    out_ref[...] += jnp.sum(d * d, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "interpret"))
def sqdiff_rowsum(a: jnp.ndarray, b: jnp.ndarray, *,
                  block_r: int = DEFAULT_BLOCK_R,
                  block_c: int = DEFAULT_BLOCK_C,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Per-row Σ(a−b)² via Pallas. a, b: (R, C) → (R,) float32.

    ``interpret=None`` resolves via the backend check (compiled on TPU,
    interpret elsewhere). Inputs are zero-padded up to block multiples
    (pad contributes (0−0)²=0, so the result is exact).
    """
    if interpret is None:
        from repro.kernels import ops
        interpret = ops._interpret()
    assert a.shape == b.shape and a.ndim == 2
    r, c = a.shape
    block_r = min(block_r, max(8, r))
    block_c = min(block_c, max(128, c))
    rp = pl.cdiv(r, block_r) * block_r
    cp = pl.cdiv(c, block_c) * block_c
    if (rp, cp) != (r, c):
        a = jnp.pad(a, ((0, rp - r), (0, cp - c)))
        b = jnp.pad(b, ((0, rp - r), (0, cp - c)))
    grid = (rp // block_r, cp // block_c)
    out = pl.pallas_call(
        _sqdiff_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_r, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:r, 0]
