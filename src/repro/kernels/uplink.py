"""Pallas TPU kernel: fused packed-uplink dequant + EF update + Eq. 5 accumulate.

The per-round hot loop used to run as separate XLA ops over fp32 buffers:
dequantize each client's levels, rebuild Θ̂, update the error-feedback
residual, then weighted-accumulate into the Eq. 5 numerator — four full
HBM passes over K × (model size).  This kernel consumes the **packed wire
format directly** (int8 level buffers from ``core/wire``) and does all of
it in one pass per (Rb, Cb) tile:

    recon      = levels[k] · scale[k]                  (dequant, in VMEM)
    num       += w[k] · recon                          (Eq. 5 numerator)
    res'[k]    = gate[k]·(v[k] − recon) + (1−gate[k])·e[k]   (EF update)

Client axis K is the **minor-most grid dimension**, so the (Rb, Cb)
numerator block is revisited across consecutive k steps and accumulated
in-place (the ``divergence.py`` reduction idiom); the residual output block
is written exactly once per (k, i, j).

Blocks default to (32, 2048): int8 operands need (32, 128)-aligned tiles
(fp32 only needs (8, 128)), and one int8 + four fp32 blocks ≈ 0.6 MiB —
comfortable in the ~16 MiB VMEM budget.

``interpret=None`` resolves via the backend check in ``kernels/ops``
(compiled on TPU, interpret elsewhere); ``kernels/ref.py`` holds the
pure-jnp oracle that doubles as the CPU fast path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 32
DEFAULT_BLOCK_C = 2048


def _uplink_kernel(lvl_ref, s_ref, w_ref, num_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)

    recon = lvl_ref[0].astype(jnp.float32) * s_ref[0]  # (Rb,Cb)·(Rb,1)
    num_ref[...] += w_ref[0] * recon


def _uplink_ef_kernel(lvl_ref, s_ref, w_ref, g_ref, v_ref, e_ref,
                      num_ref, res_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)

    recon = lvl_ref[0].astype(jnp.float32) * s_ref[0]
    num_ref[...] += w_ref[0] * recon
    g = g_ref[0]
    res_ref[0] = (g * (v_ref[0].astype(jnp.float32) - recon)
                  + (1.0 - g) * e_ref[0].astype(jnp.float32))


def _padded(levels, rowvecs, mats, block_r, block_c):
    """Zero-pad (K,R,C) operands and (K,R) row vectors to block multiples.
    Zero pads are exact: w=0 rows add nothing to num, gate=0 rows copy the
    zero-padded residual through."""
    k, r, c = levels.shape
    rp = pl.cdiv(r, block_r) * block_r
    cp = pl.cdiv(c, block_c) * block_c
    if (rp, cp) != (r, c):
        levels = jnp.pad(levels, ((0, 0), (0, rp - r), (0, cp - c)))
        mats = [jnp.pad(m, ((0, 0), (0, rp - r), (0, cp - c))) for m in mats]
        rowvecs = [jnp.pad(v, ((0, 0), (0, rp - r))) for v in rowvecs]
    rowvecs = [v.reshape(k, rp, 1) for v in rowvecs]
    return levels, rowvecs, mats, rp, cp


@functools.partial(jax.jit,
                   static_argnames=("block_r", "block_c", "interpret"))
def fused_uplink(levels: jnp.ndarray, scales: jnp.ndarray, w: jnp.ndarray, *,
                 block_r: int = DEFAULT_BLOCK_R,
                 block_c: int = DEFAULT_BLOCK_C,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Σ_k w[k,r]·scales[k,r]·levels[k,r,:] in one pass over packed levels.

    levels: (K, R, C) int levels; scales, w: (K, R) → num (R, C) f32.
    """
    if interpret is None:
        from repro.kernels import ops
        interpret = ops._interpret()
    kk, r, c = levels.shape
    assert scales.shape == (kk, r) and w.shape == (kk, r)
    block_r = min(block_r, max(32, r))
    block_c = min(block_c, max(128, c))
    levels, (s2, w2), _, rp, cp = _padded(levels, [scales, w], [],
                                          block_r, block_c)
    grid = (rp // block_r, cp // block_c, kk)
    num = pl.pallas_call(
        _uplink_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_r, block_c), lambda i, j, k: (k, i, j)),
            pl.BlockSpec((1, block_r, 1), lambda i, j, k: (k, i, 0)),
            pl.BlockSpec((1, block_r, 1), lambda i, j, k: (k, i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), jnp.float32),
        interpret=interpret,
    )(levels, s2, w2)
    return num[:r, :c]


@functools.partial(jax.jit,
                   static_argnames=("block_r", "block_c", "interpret"))
def fused_uplink_ef(levels: jnp.ndarray, scales: jnp.ndarray,
                    w: jnp.ndarray, gate: jnp.ndarray, v: jnp.ndarray,
                    e_old: jnp.ndarray, *,
                    block_r: int = DEFAULT_BLOCK_R,
                    block_c: int = DEFAULT_BLOCK_C,
                    interpret: bool | None = None
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused dequant + Eq. 5 accumulate + error-feedback residual update.

    levels: (K, R, C); scales, w, gate: (K, R); v (=Δ+e) and e_old: (K, R, C)
    → (num (R, C) f32, new_res (K, R, C) f32) where
    ``new_res = gate·(v − recon) + (1−gate)·e_old``.
    """
    if interpret is None:
        from repro.kernels import ops
        interpret = ops._interpret()
    kk, r, c = levels.shape
    assert scales.shape == (kk, r) and w.shape == (kk, r)
    assert gate.shape == (kk, r) and v.shape == (kk, r, c)
    assert e_old.shape == (kk, r, c)
    block_r = min(block_r, max(32, r))
    block_c = min(block_c, max(128, c))
    levels, (s2, w2, g2), (v_, e_), rp, cp = _padded(
        levels, [scales, w, gate], [v, e_old], block_r, block_c)
    grid = (rp // block_r, cp // block_c, kk)
    num, res = pl.pallas_call(
        _uplink_ef_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_r, block_c), lambda i, j, k: (k, i, j)),
            pl.BlockSpec((1, block_r, 1), lambda i, j, k: (k, i, 0)),
            pl.BlockSpec((1, block_r, 1), lambda i, j, k: (k, i, 0)),
            pl.BlockSpec((1, block_r, 1), lambda i, j, k: (k, i, 0)),
            pl.BlockSpec((1, block_r, block_c), lambda i, j, k: (k, i, j)),
            pl.BlockSpec((1, block_r, block_c), lambda i, j, k: (k, i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, block_r, block_c), lambda i, j, k: (k, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, cp), jnp.float32),
            jax.ShapeDtypeStruct((kk, rp, cp), jnp.float32),
        ],
        interpret=interpret,
    )(levels, s2, w2, g2, v_, e_)
    return num[:r, :c], res[:, :r, :c]
