"""Pallas TPU kernel: fused per-row scaled accumulate (Eq. 5 inner loop).

FedLDF aggregation adds a client's selected layers into the server
accumulator with a per-layer-unit weight: ``acc[r, :] += w[r] * x[r, :]``.
Doing this as separate broadcast-multiply + add in HBM costs three full
passes over the model; the fused kernel streams each (Rb, Cb) tile through
VMEM once.

The weight vector is passed as an (R, 1) operand so its block is a natural
(Rb, 1) VMEM tile; each grid cell is independent (no cross-step accumulation),
so the kernel is embarrassingly parallel over the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 8
DEFAULT_BLOCK_C = 2048


def _macc_kernel(acc_ref, x_ref, w_ref, out_ref):
    acc = acc_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)  # (Rb, 1), broadcasts over lanes
    out_ref[...] = acc + w * x


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "interpret"))
def masked_accumulate(acc: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray, *,
                      block_r: int = DEFAULT_BLOCK_R,
                      block_c: int = DEFAULT_BLOCK_C,
                      interpret: bool | None = None) -> jnp.ndarray:
    """acc + w[:, None] * x via Pallas. acc, x: (R, C); w: (R,) → (R, C) f32.

    ``interpret=None`` resolves via the backend check (compiled on TPU,
    interpret elsewhere).
    """
    if interpret is None:
        from repro.kernels import ops
        interpret = ops._interpret()
    assert acc.shape == x.shape and acc.ndim == 2
    assert w.shape == (acc.shape[0],)
    r, c = acc.shape
    block_r = min(block_r, max(8, r))
    block_c = min(block_c, max(128, c))
    rp = pl.cdiv(r, block_r) * block_r
    cp = pl.cdiv(c, block_c) * block_c
    if (rp, cp) != (r, c):
        acc = jnp.pad(acc, ((0, rp - r), (0, cp - c)))
        x = jnp.pad(x, ((0, rp - r), (0, cp - c)))
    w2 = jnp.pad(w, (0, rp - r)).reshape(rp, 1)
    grid = (rp // block_r, cp // block_c)
    out = pl.pallas_call(
        _macc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), jnp.float32),
        interpret=interpret,
    )(acc, x, w2)
    return out[:r, :c]
