"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth implementations used (a) by tests to validate the
kernels and (b) as the CPU fast path (interpret-mode Pallas is slow).
"""
from __future__ import annotations

import jax.numpy as jnp


def sqdiff_rowsum(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Per-row sum of squared differences.

    a, b: (R, C) same shape/dtype. Returns (R,) float32.
    This is the inner reduction of the paper's Eq. 3 layer divergence.
    """
    d = a.astype(jnp.float32) - b.astype(jnp.float32)
    return jnp.sum(d * d, axis=1)


def masked_accumulate(acc: jnp.ndarray, x: jnp.ndarray,
                      w: jnp.ndarray) -> jnp.ndarray:
    """acc + w[:, None] * x — the Eq. 5 per-layer weighted accumulation.

    acc: (R, C) float32 accumulator; x: (R, C) any float dtype;
    w: (R,) per-row (per layer-unit) weight. Returns (R, C) float32.
    """
    return acc + w.astype(jnp.float32)[:, None] * x.astype(jnp.float32)
