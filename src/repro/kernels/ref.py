"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth implementations used (a) by tests to validate the
kernels and (b) as the CPU fast path (interpret-mode Pallas is slow).
"""
from __future__ import annotations

import jax.numpy as jnp


def sqdiff_rowsum(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Per-row sum of squared differences.

    a, b: (R, C) same shape/dtype. Returns (R,) float32.
    This is the inner reduction of the paper's Eq. 3 layer divergence.
    """
    d = a.astype(jnp.float32) - b.astype(jnp.float32)
    return jnp.sum(d * d, axis=1)


def masked_accumulate(acc: jnp.ndarray, x: jnp.ndarray,
                      w: jnp.ndarray) -> jnp.ndarray:
    """acc + w[:, None] * x — the Eq. 5 per-layer weighted accumulation.

    acc: (R, C) float32 accumulator; x: (R, C) any float dtype;
    w: (R,) per-row (per layer-unit) weight. Returns (R, C) float32.
    """
    return acc + w.astype(jnp.float32)[:, None] * x.astype(jnp.float32)


def fused_uplink(levels: jnp.ndarray, scales: jnp.ndarray,
                 w: jnp.ndarray) -> jnp.ndarray:
    """Σ_k w[k,r]·scales[k,r]·levels[k,r,:] — dequant + Eq. 5 numerator.

    levels: (K, R, C) int levels; scales, w: (K, R). Returns (R, C) float32.
    """
    recon = levels.astype(jnp.float32) * scales.astype(jnp.float32)[..., None]
    return jnp.einsum("kr,krc->rc", w.astype(jnp.float32), recon)


def fused_uplink_ef(levels: jnp.ndarray, scales: jnp.ndarray,
                    w: jnp.ndarray, gate: jnp.ndarray, v: jnp.ndarray,
                    e_old: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused dequant + Eq. 5 numerator + error-feedback residual update.

    levels: (K, R, C); scales, w, gate: (K, R); v (=Δ+e), e_old: (K, R, C).
    Returns (num (R, C), new_res (K, R, C)) float32 with
    ``new_res = gate·(v − recon) + (1−gate)·e_old``.
    """
    recon = levels.astype(jnp.float32) * scales.astype(jnp.float32)[..., None]
    num = jnp.einsum("kr,krc->rc", w.astype(jnp.float32), recon)
    g = gate.astype(jnp.float32)[..., None]
    res = (g * (v.astype(jnp.float32) - recon)
           + (1.0 - g) * e_old.astype(jnp.float32))
    return num, res
