"""Architecture registry: the 10 assigned configs + the paper's own VGG-9.

Every entry cites its source in the module docstring and ``source`` field.
``get_config(arch_id)`` returns the exact full-scale ModelConfig;
``get_config(arch_id).reduced()`` is the CPU smoke-test variant.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

# arch id -> module name
ARCHS: dict[str, str] = {
    "qwen3-1.7b": "qwen3_1_7b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen2.5-14b": "qwen2_5_14b",
    "mamba2-780m": "mamba2_780m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen2-7b": "qwen2_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "deepseek-coder-33b": "deepseek_coder_33b",
}

ARCH_IDS = tuple(ARCHS)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch_id]}")
    return mod.config()


def vgg9():
    mod = importlib.import_module("repro.configs.vgg9_cifar10")
    return mod.config()


def vgg9_fl(algo: str = "fedldf"):
    mod = importlib.import_module("repro.configs.vgg9_cifar10")
    return mod.fl_config(algo)
