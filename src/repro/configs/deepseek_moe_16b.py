"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
(per expert), vocab=102400, 2 shared + 64 routed top-6, fine-grained experts.
[arXiv:2401.06066]

Simplification (DESIGN.md §8): DeepSeekMoE keeps its first layer dense; here
all layers are MoE with the assigned 2-shared + 64-routed top-6 structure.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=102400,
        num_experts=64,
        num_shared_experts=2,
        moe_top_k=6,
        moe_d_ff=1408,
        capacity_factor=1.25,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        source="arXiv:2401.06066 (DeepSeekMoE 16B)",
    )
