"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE + dynamic resolution. [arXiv:2409.12191]

Vision tower (ViT) is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (frontend_dim=1280, the Qwen2-VL ViT width);
the language backbone fuses them into the token stream (early fusion) and is
implemented in full, including M-RoPE with sections (16, 24, 24).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        mrope=True,
        mrope_sections=(16, 24, 24),   # Σ = 64 = head_dim/2
        frontend_dim=1280,
        rope_theta=1_000_000.0,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        source="arXiv:2409.12191 (Qwen2-VL-2B)",
    )
