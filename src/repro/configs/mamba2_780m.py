"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) d_ff=0
vocab=50280, ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,                 # Mamba-2 blocks have no separate MLP
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,        # d_inner = 3072 -> 48 SSD heads
        ssm_expand=2,
        ssm_chunk=128,
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        source="arXiv:2405.21060 (Mamba-2 780m)",
    )
