"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + mamba heads in each block.
[arXiv:2411.13676]

Simplifications recorded in DESIGN.md §8: meta-tokens and the per-layer
sliding/global attention mix are replaced by full attention in every block;
the parallel attn ∥ SSM head structure (the paper's core idea) is kept.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,          # 25 × 64 = 1600
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=128,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        source="arXiv:2411.13676 (Hymba-1.5B)",
    )
