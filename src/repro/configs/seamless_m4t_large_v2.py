"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206, encoder-decoder, multimodal. [arXiv:2308.11596]

Per the assignment the modality frontend (mel-spectrogram + conv feature
extractor / w2v-BERT speech encoder frontend) is a STUB: ``input_specs()``
provides precomputed frame embeddings (frontend_dim=1024). The transformer
encoder-decoder backbone is implemented in full (the conformer encoder is
simplified to a transformer encoder; DESIGN.md §8).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        num_layers=24,           # decoder
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256206,
        frontend_dim=1024,
        rope_theta=10_000.0,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        source="arXiv:2308.11596 (SeamlessM4T large v2)",
    )
