"""vgg9-cifar10 — the paper's own experimental setup (§III-A).

VGG-9 (8 conv + 1 FC), CIFAR-10-like data, N=50 clients, K=20 participants
per round, FedLDF n=4 (80 % uplink saving), T=1000 rounds, IID and
Dirichlet(α=1) splits.
"""
from repro.federated.server import FLConfig
from repro.models.cnn import VGGConfig


def config() -> VGGConfig:
    return VGGConfig()


def fl_config(algo: str = "fedldf") -> FLConfig:
    return FLConfig(algo=algo, num_clients=50, clients_per_round=20,
                    top_n=4, local_steps=1, lr=0.05, mode="vmap",
                    fedadp_keep=0.2, batch_per_client=32)
