"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 (per expert), vocab=202048, MoE 128 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E family card]

Simplification (DESIGN.md §8): Maverick interleaves dense and MoE layers;
here every layer is MoE with 1 shared + 128 routed top-1 experts, matching
the assigned dims. FedLDF beyond-paper option: ``expert_units=True`` treats
the expert bank as divergence units for expert-granular selective upload.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,              # shared-expert width
        vocab_size=202048,
        num_experts=128,
        num_shared_experts=1,
        moe_top_k=1,
        moe_d_ff=8192,
        capacity_factor=1.25,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        source="hf:meta-llama/Llama-4-Scout-17B-16E (family card; Maverick dims)",
    )
