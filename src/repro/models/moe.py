"""Mixture-of-Experts layer (llama4-style top-1 and deepseek-style
shared+routed top-k), GShard/GSPMD-friendly.

Dispatch is capacity-based: tokens are scattered into an (E, C, D) buffer
(positions via a cumulative-sum over the routing one-hot), expert FFNs run as
one batched einsum ``ecd,edf->ecf`` — so compiled FLOPs reflect *active*
parameters (top-k), not all experts, and the expert dimension shards cleanly
over the 'model' mesh axis (the token→expert reshard is the all-to-all).
Overflow beyond capacity is dropped (combine weights renormalised), the
standard trade for static shapes on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, dtype_of
from repro.models.layers import init_dense, init_mlp, mlp_fwd


def init_moe(key, cfg: ModelConfig):
    dt = dtype_of(cfg.param_dtype)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": init_dense(ks[0], d, e, jnp.float32),  # routing in fp32
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) / d**0.5).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) / d**0.5).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) / f**0.5).astype(dt),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = init_mlp(ks[4], cfg,
                               d_ff=cfg.num_shared_experts * cfg.moe_d_ff)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.num_experts)
    return max(cap, cfg.moe_top_k)


def moe_fwd(p, x: jnp.ndarray, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D). Returns (out, aux) with load-balance loss."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.moe_top_k
    cap = _capacity(t, cfg)
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, eidx = jax.lax.top_k(probs, k)                   # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renormalise

    # Position of each (token, choice) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(eidx, e, dtype=jnp.int32)           # (T, k, E)
    flat = onehot.reshape(t * k, e)
    pos_in_e = jnp.cumsum(flat, axis=0) - flat                  # (T*k, E)
    pos = (pos_in_e * flat).sum(-1).reshape(t, k)               # (T, k)
    keep = pos < cap
    gate_vals = gate_vals * keep

    # Scatter tokens to (E, C, D).
    buf = jnp.zeros((e, cap, d), dtype=x.dtype)
    eflat = eidx.reshape(-1)
    pflat = jnp.where(keep.reshape(-1), pos.reshape(-1), cap)   # dropped -> OOB
    src = jnp.repeat(xt, k, axis=0)
    buf = buf.at[eflat, pflat].set(src, mode="drop")

    # Expert FFNs (SwiGLU), batched over E — shards over 'model'.
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])        # (E, C, D)

    # Gather back and combine with gate values.
    gathered = out_buf[eflat, jnp.minimum(pflat, cap - 1)]      # (T*k, D)
    gathered = gathered.reshape(t, k, d) * gate_vals[..., None].astype(x.dtype)
    out = gathered.sum(axis=1)

    if cfg.num_shared_experts > 0:
        out = out + mlp_fwd(p["shared"], xt)

    # Switch-style load-balance aux loss.
    me = probs.mean(axis=0)                                     # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[eidx.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)
    return out.reshape(b, s, d), aux
