"""Shared neural-net building blocks (pure JAX, functional params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, dtype_of


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def init_dense(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)


def init_embed(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP used by the Qwen/Llama/DeepSeek family."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def lora_dense(x, w, lora=None, name=None):
    """Dense projection with an optional LoRA adapter delta.

    ``y = x @ w`` plus, when ``lora`` (the enclosing module's adapter dict —
    see models/lora.py) holds factors for ``name``, the low-rank update
    ``(x @ a) @ b``. Factors are cast to the activation dtype; ``b`` is
    zero-initialised at injection so the adapted forward is bit-identical
    to the base until the factors train away from zero.
    """
    y = jnp.einsum("...d,df->...f", x, w)
    if lora is not None and name in lora:
        f = lora[name]
        z = jnp.einsum("...d,dr->...r", x, f["a"].astype(x.dtype))
        y = y + jnp.einsum("...r,rf->...f", z, f["b"].astype(x.dtype))
    return y


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    dt = dtype_of(cfg.param_dtype)
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, cfg.d_model, f, dt),
        "w_up": init_dense(k2, cfg.d_model, f, dt),
        "w_down": init_dense(k3, f, cfg.d_model, dt),
    }


def mlp_fwd(p, x):
    lora = p.get("lora")
    if lora is None:
        return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    g = lora_dense(x, p["w_gate"], lora, "w_gate")
    u = lora_dense(x, p["w_up"], lora, "w_up")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return lora_dense(h, p["w_down"], lora, "w_down")
