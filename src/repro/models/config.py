"""Model configuration for the architecture zoo.

One dataclass covers all six assigned families (dense / moe / ssm / hybrid /
audio enc-dec / vlm); family-specific fields are zero/None when unused.
``reduced()`` produces the CPU-smoke-test variant required per architecture
(≤2 layers, d_model ≤ 512, ≤4 experts) while preserving the family wiring.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    source: str = ""            # citation (paper/model card)

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    mrope: bool = False                      # qwen2-vl M-RoPE
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    sliding_window: int = 0                  # 0 = full attention

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    expert_units: bool = False               # beyond-paper: expert-level FedLDF units

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    ssm_expand: int = 2

    # encoder-decoder (audio)
    encoder_layers: int = 0                  # >0 => enc-dec
    frontend_dim: int = 0                    # stub embedding dim (audio/vlm)

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    tie_embeddings: bool = False

    # performance knobs (§Perf hillclimb levers)
    remat_blocks: bool = False   # jax.checkpoint around each block in bwd
    attn_chunk: int = 1024       # flash KV-chunk length (carry-rewrite trade)
    attn_probs_bf16: bool = False  # store attention probabilities in bf16

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.num_heads)  # 0 heads: attn-free

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count N (for 6·N·D model-FLOPs)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        per_layer = 0
        if self.family in ("dense", "moe", "hybrid", "vlm", "audio"):
            qdim = self.num_heads * self.hd
            kvdim = self.num_kv_heads * self.hd
            per_layer += d * qdim + 2 * d * kvdim + qdim * d      # q,k,v,o
        if self.family == "hybrid" or self.family == "ssm":
            di, n, h = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            per_layer += d * (2 * di + 2 * n + h) + di * d        # in/out proj
        if self.num_experts > 0:
            per_layer += (self.num_experts * 3 * d * self.moe_d_ff
                          + self.num_shared_experts * 3 * d * self.moe_d_ff
                          + d * self.num_experts)
        elif f > 0:
            per_layer += 3 * d * f                                # SwiGLU
        total = self.num_layers * per_layer
        if self.is_encdec:
            enc_layer = (d * self.num_heads * self.hd * 2
                         + 2 * d * self.num_kv_heads * self.hd + 3 * d * f)
            total += self.encoder_layers * enc_layer
            total += self.num_layers * (2 * d * self.num_kv_heads * self.hd
                                        + 2 * d * self.num_heads * self.hd)
        total += v * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top-k routed)."""
        if self.num_experts == 0:
            return self.param_count()
        d = self.d_model
        dense_like = self.param_count() - self.num_layers * (
            self.num_experts * 3 * d * self.moe_d_ff)
        active_moe = self.num_layers * self.moe_top_k * 3 * d * self.moe_d_ff
        return int(dense_like + active_moe)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant: same family wiring, tiny dims."""
        nh = min(self.num_heads, 4)
        nkv = max(1, min(self.num_kv_heads, nh))
        while nh % nkv:
            nkv -= 1
        if self.mrope:
            # rescale sections to the reduced head_dim (32 -> half = 16)
            mrope_sections = (4, 6, 6)
        else:
            mrope_sections = self.mrope_sections
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            encoder_layers=2 if self.is_encdec else 0,
            d_model=128,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            mrope_sections=mrope_sections,
            num_experts=min(self.num_experts, 4),
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=64 if self.num_experts else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32,
            ssm_chunk=16,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            frontend_dim=128 if self.frontend_dim else 0,
        )


def dtype_of(name: str):
    import jax.numpy as jnp
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]
