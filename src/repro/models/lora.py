"""LoRA-style adapters over the transformer zoo [arXiv:2106.09685 idiom].

``inject_lora`` drops low-rank factor pairs ``{"a": (L, d_in, r),
"b": (L, r, d_out)}`` next to the stacked dense projections they adapt
(``blocks["attn"]["lora"]["wq"]``, ...). ``b`` is zero-initialised, so the
adapted forward equals the base forward bit-for-bit at injection time —
training moves only the factors. The forward hookup lives in
:func:`repro.models.layers.lora_dense`.

Combined with :class:`repro.core.partition.ParamPartition` (see
``lora_partition``) this is the adapter-only uplink workload: the frozen
base stays device-resident and is broadcast once, the wire carries factors
only, and FedLDF's per-layer divergence (Eq. 3) scores per-depth adapter
units — the stacked (L, ...) leading axis folds into the existing
``blocks/i`` units of :class:`repro.core.units.UnitMap`.

Adapted projections per block module (only those present are touched):

    attn: wq wk wv wo          (dense / moe / hybrid / enc / dec families)
    mlp:  w_gate w_up w_down   (all non-moe FFN blocks)
    ssm:  in_proj out_proj     (mamba2 / hybrid families)

Cross-attention and MoE expert tensors are intentionally not adapted —
the classic LoRA recipe targets self-attention + FFN, and expert tensors
carry an extra (E,) axis the factor layout does not model.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import ParamPartition

Pytree = Any

# module-name -> projection names eligible for adapters (ndim-3 stacked
# (L, d_in, d_out) leaves only; missing modules/names are skipped).
LORA_TARGETS: Mapping[str, Tuple[str, ...]] = {
    "attn": ("wq", "wk", "wv", "wo"),
    "mlp": ("w_gate", "w_up", "w_down"),
    "ssm": ("in_proj", "out_proj"),
}

# stacked-block subtrees adapters may live under (see transformer.init_params)
LORA_SUBTREES: Tuple[str, ...] = ("blocks", "enc_blocks")


def inject_lora(key, params: Pytree, rank: int,
                targets: Optional[Mapping[str, Tuple[str, ...]]] = None,
                subtrees: Tuple[str, ...] = LORA_SUBTREES) -> Pytree:
    """Returns a copy of ``params`` with adapter factors injected.

    ``rank`` is clipped per-projection to ``min(rank, d_in, d_out)``.
    ``a`` ~ N(0, 1/d_in), ``b`` = 0 (forward-exact at init). Raises
    ValueError if no eligible projection exists — an empty adapter set
    would make the trainable partition empty.
    """
    if rank < 1:
        raise ValueError(f"lora rank must be >= 1, got {rank}")
    targets = LORA_TARGETS if targets is None else targets
    out = dict(params)
    injected = 0
    for sub in subtrees:
        if sub not in params:
            continue
        blocks = dict(params[sub])
        for mod, projs in targets.items():
            if mod not in blocks:
                continue
            mdict = dict(blocks[mod])
            lora = dict(mdict.get("lora", {}))
            for name in projs:
                w = mdict.get(name)
                if w is None or getattr(w, "ndim", 0) != 3:
                    continue
                depth, din, dout = w.shape
                r = min(rank, din, dout)
                key, ka = jax.random.split(key)
                a = (jax.random.normal(ka, (depth, din, r))
                     / np.sqrt(din)).astype(w.dtype)
                lora[name] = {"a": a, "b": jnp.zeros((depth, r, dout),
                                                     w.dtype)}
                injected += 1
            if lora:
                mdict["lora"] = lora
                blocks[mod] = mdict
        out[sub] = blocks
    if injected == 0:
        raise ValueError(
            "inject_lora found no eligible projection: params has none of "
            f"{sorted(targets)} with stacked (L, d_in, d_out) leaves under "
            f"{subtrees}")
    return out


def lora_partition(params: Pytree) -> ParamPartition:
    """Trainable = every leaf under a ``lora`` path segment; rest frozen.

    Pass the result as ``FLConfig(partition=...)`` to get the adapter-only
    uplink: the base model is broadcast once and never travels the wire.
    """
    return ParamPartition.by_substring(params, "lora")
