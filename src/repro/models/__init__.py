"""Model zoo: transformer families + VGG-9 (paper's model)."""
from repro.models import attention, cnn, config, decode, layers, moe, ssm, transformer
from repro.models.config import ModelConfig, dtype_of

__all__ = ["attention", "cnn", "config", "decode", "layers", "moe", "ssm",
           "transformer", "ModelConfig", "dtype_of"]
