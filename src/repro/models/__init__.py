"""Model zoo: transformer families + VGG-9 (paper's model)."""
from repro.models import (attention, cnn, config, decode, layers, lora, moe,
                          ssm, transformer)
from repro.models.config import ModelConfig, dtype_of
from repro.models.lora import inject_lora, lora_partition

__all__ = ["attention", "cnn", "config", "decode", "layers", "lora", "moe",
           "ssm", "transformer", "ModelConfig", "dtype_of", "inject_lora",
           "lora_partition"]
