"""Composable transformer LM covering the assigned families.

Parameters are organised for FedLDF layer-units (see core/units.py):

    params = {
      "embed":      {"tok": (V, D)}                       # unit "embed"
      "blocks":     {...leaves stacked (L, ...)}          # units blocks/0..L-1
      "enc_blocks": {...}            (enc-dec only)       # units enc_blocks/*
      "enc_embed":  {...}            (audio/vlm frontends)
      "final":      {"norm": (D,) [, "head": (D, V)]}     # unit "final"
    }

Blocks execute under ``lax.scan`` (stacked leaves), which keeps HLO size
O(1) in depth — essential for compiling 48-62 layer configs on the dry-run
host — and makes per-depth divergence a batched row-reduction (the Pallas
kernel's layout).

Decode uses a ring-buffer KV cache; ``sliding_window`` caps the buffer so
full-attention architectures stay sub-quadratic-memory on ``long_500k``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig, dtype_of
from repro.models.layers import (init_dense, init_embed, init_mlp,
                                 lora_dense, mlp_fwd, rms_norm)

Pytree = Any


# ======================================================================
# Init
# ======================================================================
def _init_attn(key, cfg: ModelConfig, cross: bool = False):
    dt = dtype_of(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.hd
    qdim, kvdim = cfg.num_heads * hd, cfg.num_kv_heads * hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, qdim, dt),
        "wk": init_dense(ks[1], d, kvdim, dt),
        "wv": init_dense(ks[2], d, kvdim, dt),
        "wo": init_dense(ks[3], qdim, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qdim,), dt)
        p["bk"] = jnp.zeros((kvdim,), dt)
        p["bv"] = jnp.zeros((kvdim,), dt)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _init_block(key, cfg: ModelConfig, kind: str):
    """kind: dense | moe | ssm | hybrid | enc | dec"""
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg.param_dtype)
    p: dict = {"ln1": jnp.ones((cfg.d_model,), dt)}
    if kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
        return p
    if kind in ("dense", "moe", "enc", "dec", "hybrid"):
        p["attn"] = _init_attn(ks[0], cfg)
    if kind == "hybrid":
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg)
    if kind == "dec":
        p["ln_cross"] = jnp.ones((cfg.d_model,), dt)
        p["cross"] = _init_attn(ks[2], cfg, cross=True)
    p["ln2"] = jnp.ones((cfg.d_model,), dt)
    if kind == "moe":
        p["moe"] = moe_mod.init_moe(ks[3], cfg)
    else:
        p["mlp"] = init_mlp(ks[4], cfg)
    return p


def _stack_blocks(key, cfg: ModelConfig, kind: str, depth: int):
    keys = jax.random.split(key, depth)
    return jax.vmap(lambda k: _init_block(k, cfg, kind))(keys)


def block_kind(cfg: ModelConfig) -> str:
    return {"dense": "dense", "vlm": "dense", "moe": "moe",
            "ssm": "ssm", "hybrid": "hybrid", "audio": "dec"}[cfg.family]


def init_params(key, cfg: ModelConfig) -> Pytree:
    ks = jax.random.split(key, 5)
    dt = dtype_of(cfg.param_dtype)
    params: Pytree = {
        "embed": {"tok": init_embed(ks[0], cfg.vocab_size, cfg.d_model, dt)},
        "blocks": _stack_blocks(ks[1], cfg, block_kind(cfg), cfg.num_layers),
        "final": {"norm": jnp.ones((cfg.d_model,), dt)},
    }
    if not cfg.tie_embeddings:
        params["final"]["head"] = init_dense(ks[2], cfg.d_model,
                                             cfg.vocab_size, dt)
    if cfg.is_encdec:
        params["enc_blocks"] = _stack_blocks(ks[3], cfg, "enc",
                                             cfg.encoder_layers)
        params["enc_embed"] = {
            "proj": init_dense(ks[4], cfg.frontend_dim or cfg.d_model,
                               cfg.d_model, dt),
            "norm": jnp.ones((cfg.d_model,), dt),
        }
    elif cfg.family == "vlm" and cfg.frontend_dim:
        params["enc_embed"] = {
            "proj": init_dense(ks[4], cfg.frontend_dim, cfg.d_model, dt),
            "norm": jnp.ones((cfg.d_model,), dt),
        }
    return params


# ======================================================================
# Attention wrapper (projection + rope + attend)
# ======================================================================
def _qkv(p, cfg: ModelConfig, x, positions):
    b, s, d = x.shape
    hd = cfg.hd
    lora = p.get("lora")
    q = lora_dense(x, p["wq"], lora, "wq")
    k = lora_dense(x, p["wk"], lora, "wk")
    v = lora_dense(x, p["wv"], lora, "wv")
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions is not None:
        if cfg.mrope:
            q = attn.apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = attn.apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = attn.apply_rope(q, positions, cfg.rope_theta)
            k = attn.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _self_attn(p, cfg: ModelConfig, x, positions, *, causal=True):
    s = x.shape[1]
    q, k, v = _qkv(p, cfg, x, positions)
    pos1d = positions[0, 0] if cfg.mrope else positions[0]
    o = attn.attend(q, k, v, q_pos=pos1d, kv_pos=pos1d, causal=causal,
                    window=cfg.sliding_window, chunk=cfg.attn_chunk,
                    probs_bf16=cfg.attn_probs_bf16)
    return lora_dense(o.reshape(x.shape[0], s, -1), p["wo"],
                      p.get("lora"), "wo")


def _cross_attn(p, cfg: ModelConfig, x, enc_kv):
    b, s, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,df->bsf", x, p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k, v = enc_kv  # precomputed (B, Senc, KV, hd)
    o = attn.attend(q, k, v,
                    q_pos=jnp.zeros((s,), jnp.int32),
                    kv_pos=jnp.zeros((k.shape[1],), jnp.int32),
                    causal=False, window=0)
    return jnp.einsum("bsf,fd->bsd", o.reshape(b, s, -1), p["wo"])


# ======================================================================
# Block forward (full sequence)
# ======================================================================
def _block_fwd(blk, cfg: ModelConfig, x, positions, kind: str,
               enc_kv=None):
    aux = jnp.float32(0.0)
    h = rms_norm(x, blk["ln1"])
    if kind == "ssm":
        return x + ssm_mod.ssd_fwd(blk["ssm"], h, cfg), aux
    if kind == "hybrid":
        mix = 0.5 * (_self_attn(blk["attn"], cfg, h, positions)
                     + ssm_mod.ssd_fwd(blk["ssm"], h, cfg))
        x = x + mix
    else:
        causal = kind != "enc"
        x = x + _self_attn(blk["attn"], cfg, h, positions, causal=causal)
    if kind == "dec" and enc_kv is not None:
        x = x + _cross_attn(blk["cross"], cfg,
                            rms_norm(x, blk["ln_cross"]), enc_kv)
    h2 = rms_norm(x, blk["ln2"])
    if kind == "moe":
        out, aux = moe_mod.moe_fwd(blk["moe"], h2, cfg)
        x = x + out
    else:
        x = x + mlp_fwd(blk["mlp"], h2)
    return x, aux


def _run_stack(blocks, cfg: ModelConfig, x, positions, kind: str,
               enc_kv=None):
    """enc_kv: optional per-layer stacked (L, B, Se, KV, hd) K/V pair —
    scanned alongside the blocks so each decoder layer sees its own slice."""

    def body(carry, xs):
        x, aux = carry
        if enc_kv is not None:
            blk, ek, ev = xs
            x, a = _block_fwd(blk, cfg, x, positions, kind, (ek, ev))
        else:
            x, a = _block_fwd(blk := xs, cfg, x, positions, kind, None)
        return (x, aux + a), None

    if cfg.remat_blocks:
        # activation checkpointing: store only block boundaries, recompute
        # internals in the backward pass (the §Perf memory-term lever).
        body = jax.checkpoint(body)

    xs = (blocks, enc_kv[0], enc_kv[1]) if enc_kv is not None else blocks
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    return x, aux


# ======================================================================
# Full forward passes
# ======================================================================
def _positions_for(cfg: ModelConfig, batch: int, seq: int, offset=0):
    if cfg.mrope:
        return attn.text_mrope_positions(batch, seq) + offset
    return jnp.broadcast_to(jnp.arange(seq)[None, :], (batch, seq)) + offset


def _encode(params, cfg: ModelConfig, enc_inputs):
    """Audio/VLM frontend stub output -> encoder stack -> (B, Senc, D)."""
    x = jnp.einsum("bsf,fd->bsd", enc_inputs, params["enc_embed"]["proj"])
    x = rms_norm(x, params["enc_embed"]["norm"])
    pos = _positions_for(cfg, x.shape[0], x.shape[1])
    x, _ = _run_stack(params["enc_blocks"], cfg, x, pos, "enc")
    return x


def _embed_tokens(params, cfg: ModelConfig, tokens, embeddings=None):
    x = params["embed"]["tok"][tokens]
    if embeddings is not None and cfg.family == "vlm":
        # VLM early-fusion stub: add projected patch embeddings to the first
        # S_vis token slots (precomputed by the (stubbed) vision tower).
        proj = jnp.einsum("bsf,fd->bsd", embeddings,
                          params["enc_embed"]["proj"])
        proj = rms_norm(proj, params["enc_embed"]["norm"])
        svis = proj.shape[1]
        x = x.at[:, :svis, :].add(proj.astype(x.dtype))
    return x.astype(dtype_of(cfg.compute_dtype))


def forward(params: Pytree, cfg: ModelConfig, tokens: jnp.ndarray,
            enc_inputs: Optional[jnp.ndarray] = None,
            embeddings: Optional[jnp.ndarray] = None):
    """Training forward. tokens: (B, S) int32 -> logits (B, S, V), aux."""
    b, s = tokens.shape
    x = _embed_tokens(params, cfg, tokens, embeddings)
    pos = _positions_for(cfg, b, s)
    enc_kv = None
    if cfg.is_encdec:
        assert enc_inputs is not None, "enc-dec model needs enc_inputs"
        enc_out = _encode(params, cfg, enc_inputs)
        enc_kv = _enc_kv_all(params, cfg, enc_out)
    x, aux = _run_stack(params["blocks"], cfg, x, pos, block_kind(cfg), enc_kv)
    x = rms_norm(x, params["final"]["norm"])
    head = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["final"]["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return logits, aux


def _enc_kv_all(params, cfg: ModelConfig, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output.

    Returns stacked (L, B, Senc, KV, hd) pair consumed inside the decoder
    scan (the xs argument), so cross-K/V is computed once, not per step.
    """
    b, se, _ = enc_out.shape
    hd = cfg.hd

    def per_layer(blk):
        k = jnp.einsum("bsd,df->bsf", enc_out, blk["cross"]["wk"])
        v = jnp.einsum("bsd,df->bsf", enc_out, blk["cross"]["wv"])
        return (k.reshape(b, se, cfg.num_kv_heads, hd),
                v.reshape(b, se, cfg.num_kv_heads, hd))

    return jax.vmap(per_layer)(params["blocks"])


# ======================================================================
# Loss
# ======================================================================
def lm_loss(params: Pytree, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    """Next-token cross-entropy (+ MoE aux). batch: tokens, labels[, enc]."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          enc_inputs=batch.get("enc_inputs"),
                          embeddings=batch.get("embeddings"))
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    return loss + 0.01 * aux


def make_lm_loss(cfg: ModelConfig):
    """A ``loss_fn(params, batch)`` closure over ``cfg`` for the FL drivers.

    The drivers key their jit cache on loss_fn identity — build this once
    per run and reuse the same object across rounds and drivers.
    """
    def loss_fn(params: Pytree, batch: dict) -> jnp.ndarray:
        return lm_loss(params, cfg, batch)

    return loss_fn
