"""VGG-9 CNN — the paper's experimental model (§III-A).

8 conv layers + 1 FC, normalisation + max-pooling following conv pairs
(32×32 → 2×2 spatial). Params are organised one top-level key per layer so
the FedLDF :class:`UnitMap` yields exactly the paper's L = 9 layer units.

Note on BN: FL with running BN statistics is ill-defined under parameter
averaging; we use batch-statistics normalisation with learned scale/bias in
both train and eval (common in FL simulations), recorded in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class VGGConfig:
    name: str = "vgg9-cifar10"
    channels: tuple[int, ...] = (64, 64, 128, 128, 256, 256, 512, 512)
    pool_after: tuple[int, ...] = (1, 3, 5, 7)   # conv indices
    num_classes: int = 10
    image_size: int = 32
    in_channels: int = 3
    source: str = "paper §III-A (VGG-9: 8 conv + 1 FC)"

    @property
    def num_layers(self) -> int:  # L in the paper
        return len(self.channels) + 1

    def reduced(self) -> "VGGConfig":
        return dataclasses.replace(
            self, name=self.name + "-reduced",
            channels=(8, 8, 16, 16), pool_after=(1, 3))

    def fc_in(self) -> int:
        spatial = self.image_size // (2 ** len(self.pool_after))
        return spatial * spatial * self.channels[-1]


def init_params(key, cfg: VGGConfig) -> Pytree:
    params: Pytree = {}
    cin = cfg.in_channels
    keys = jax.random.split(key, len(cfg.channels) + 1)
    for i, cout in enumerate(cfg.channels):
        fan_in = 3 * 3 * cin
        params[f"conv{i}"] = {
            "w": (jax.random.normal(keys[i], (3, 3, cin, cout))
                  * np.sqrt(2.0 / fan_in)).astype(jnp.float32),
            "b": jnp.zeros((cout,), jnp.float32),
            "scale": jnp.ones((cout,), jnp.float32),
            "bias": jnp.zeros((cout,), jnp.float32),
        }
        cin = cout
    params["fc"] = {
        "w": (jax.random.normal(keys[-1], (cfg.fc_in(), cfg.num_classes))
              * np.sqrt(1.0 / cfg.fc_in())).astype(jnp.float32),
        "b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }
    return params


def _batch_norm(x, scale, bias, eps=1e-5):
    mean = x.mean(axis=(0, 1, 2), keepdims=True)
    var = x.var(axis=(0, 1, 2), keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * scale + bias


def forward(params: Pytree, cfg: VGGConfig, images: jnp.ndarray):
    """images: (B, H, W, C) float32 -> logits (B, num_classes)."""
    x = images
    for i in range(len(cfg.channels)):
        p = params[f"conv{i}"]
        x = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = _batch_norm(x + p["b"], p["scale"], p["bias"])
        x = jax.nn.relu(x)
        if i in cfg.pool_after:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
    x = x.reshape(x.shape[0], -1)
    return x @ params["fc"]["w"] + params["fc"]["b"]


def classify_loss(params: Pytree, cfg: VGGConfig, batch: dict):
    """batch: {images: (B,H,W,C), labels: (B,)}."""
    logits = forward(params, cfg, batch["images"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)
    return nll.mean()


def accuracy(params: Pytree, cfg: VGGConfig, batch: dict):
    logits = forward(params, cfg, batch["images"])
    return (jnp.argmax(logits, -1) == batch["labels"]).mean()
