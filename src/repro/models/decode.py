"""Serving path: prefill + single-token decode with ring-buffer KV cache.

- ``init_cache``  — allocate the per-family cache pytree (attention KV ring
  buffers, SSM recurrent state, enc-dec cross-K/V).
- ``prefill``     — full forward that also materialises the cache.
- ``decode_step`` — ONE new token against the cache (the program lowered for
  the ``decode_32k`` / ``long_500k`` input shapes).

Ring buffer: the KV buffer has ``W`` slots; token at absolute position ``p``
writes slot ``p mod W``. With ``W = sliding_window`` this *is* sliding-window
attention (what makes dense architectures eligible for ``long_500k``); with
``W = seq_len`` it is an ordinary full cache. Keys are stored post-RoPE, so
decode attention needs only an occupancy mask, not stored positions.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig, dtype_of
from repro.models.layers import mlp_fwd, rms_norm
from repro.models.transformer import (_embed_tokens, _enc_kv_all, _encode,
                                      _qkv, block_kind)

Pytree = Any


def cache_window(cfg: ModelConfig, seq_len: int) -> int:
    return min(cfg.sliding_window, seq_len) if cfg.sliding_window else seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               enc_len: int = 0) -> Pytree:
    """Empty cache for ``seq_len`` context. Leaves stacked over layers."""
    dt = dtype_of(cfg.compute_dtype)
    l, hd, kvh = cfg.num_layers, cfg.hd, cfg.num_kv_heads
    w = cache_window(cfg, seq_len)
    kind = block_kind(cfg)
    cache: Pytree = {"pos": jnp.zeros((), jnp.int32)}
    if kind in ("dense", "moe", "hybrid", "dec"):
        cache["k"] = jnp.zeros((l, batch, w, kvh, hd), dt)
        cache["v"] = jnp.zeros((l, batch, w, kvh, hd), dt)
    if kind in ("ssm", "hybrid"):
        sc = ssm_mod.init_ssm_cache(cfg, batch, dt)
        cache["ssm_conv"] = jnp.broadcast_to(
            sc["conv"][None], (l,) + sc["conv"].shape).astype(dt)
        cache["ssm_state"] = jnp.broadcast_to(
            sc["state"][None], (l,) + sc["state"].shape)
    if cfg.is_encdec:
        cache["cross_k"] = jnp.zeros((l, batch, enc_len, kvh, hd), dt)
        cache["cross_v"] = jnp.zeros((l, batch, enc_len, kvh, hd), dt)
    return cache


# ----------------------------------------------------------------------
# Prefill
# ----------------------------------------------------------------------
def prefill(params: Pytree, cfg: ModelConfig, tokens: jnp.ndarray,
            enc_inputs: Optional[jnp.ndarray] = None,
            embeddings: Optional[jnp.ndarray] = None,
            max_len: Optional[int] = None):
    """Forward over the prompt; returns (last-position logits, cache).

    ``max_len`` sets cache capacity (≥ prompt length); when omitted the
    cache is exactly prompt-sized and subsequent decode steps roll the ring
    buffer (oldest entry evicted).
    """
    b, s = tokens.shape
    kind = block_kind(cfg)
    w = cache_window(cfg, max_len or s)
    x = _embed_tokens(params, cfg, tokens, embeddings)
    if cfg.mrope:
        positions = attn.text_mrope_positions(b, s)
        pos1d = positions[0, 0]
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        pos1d = positions[0]

    enc_kv = None
    if cfg.is_encdec:
        enc_out = _encode(params, cfg, enc_inputs)
        enc_kv = _enc_kv_all(params, cfg, enc_out)

    def body(x, xs):
        blk = xs[0] if cfg.is_encdec else xs
        ekv = (xs[1], xs[2]) if cfg.is_encdec else None
        ys = {}
        h = rms_norm(x, blk["ln1"])
        if kind in ("dense", "moe", "hybrid", "dec"):
            q, k, v = _qkv(blk["attn"], cfg, h, positions)
            o = attn.attend(q, k, v, q_pos=pos1d, kv_pos=pos1d, causal=True,
                            window=cfg.sliding_window)
            o = jnp.einsum("bsf,fd->bsd", o.reshape(b, s, -1),
                           blk["attn"]["wo"])
            # keep the last min(s, w) (post-RoPE) keys/values, ring-aligned
            # so that absolute position p sits in slot p mod w.
            if w >= s:
                kw = jnp.pad(k, ((0, 0), (0, w - s), (0, 0), (0, 0)))
                vw = jnp.pad(v, ((0, 0), (0, w - s), (0, 0), (0, 0)))
            else:
                kw = jax.lax.dynamic_slice_in_dim(k, s - w, w, axis=1)
                vw = jax.lax.dynamic_slice_in_dim(v, s - w, w, axis=1)
                shift = (s - w) % w
                kw = jnp.roll(kw, shift=shift, axis=1)
                vw = jnp.roll(vw, shift=shift, axis=1)
            ys["k"], ys["v"] = kw, vw
            if kind == "hybrid":
                o2, sc = ssm_mod.ssd_fwd(blk["ssm"], h, cfg, return_cache=True)
                ys["ssm_conv"], ys["ssm_state"] = sc["conv"], sc["state"]
                o = 0.5 * (o + o2)
            x = x + o
        else:  # pure ssm
            o, sc = ssm_mod.ssd_fwd(blk["ssm"], h, cfg, return_cache=True)
            ys["ssm_conv"], ys["ssm_state"] = sc["conv"], sc["state"]
            x = x + o
            h2 = rms_norm(x, blk["ln2"]) if "ln2" in blk else None
            if h2 is not None:
                x = x + mlp_fwd(blk["mlp"], h2)
            return x, ys
        if kind == "dec" and ekv is not None:
            from repro.models.transformer import _cross_attn
            x = x + _cross_attn(blk["cross"], cfg,
                                rms_norm(x, blk["ln_cross"]), ekv)
            ys["cross_k"], ys["cross_v"] = ekv
        h2 = rms_norm(x, blk["ln2"])
        if kind == "moe":
            out, _ = moe_mod.moe_fwd(blk["moe"], h2, cfg)
            x = x + out
        else:
            x = x + mlp_fwd(blk["mlp"], h2)
        return x, ys

    xs = (params["blocks"],) + tuple(enc_kv) if cfg.is_encdec \
        else params["blocks"]
    x, ys = jax.lax.scan(body, x, xs)

    x = rms_norm(x, params["final"]["norm"])
    head = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["final"]["head"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1, :], head.astype(x.dtype))

    cache = init_cache(cfg, b, max_len or s, enc_len=enc_inputs.shape[1]
                       if enc_inputs is not None else 0)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    for key in ("k", "v", "ssm_conv", "ssm_state", "cross_k", "cross_v"):
        if key in ys:
            cache[key] = ys[key].astype(cache[key].dtype)
    return logits, cache


# ----------------------------------------------------------------------
# Decode step
# ----------------------------------------------------------------------
def decode_step(params: Pytree, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: Pytree):
    """One token. tokens: (B, 1) int32. Returns (logits (B, V), cache')."""
    b = tokens.shape[0]
    kind = block_kind(cfg)
    pos = cache["pos"]
    x = _embed_tokens(params, cfg, tokens)
    if cfg.mrope:
        positions = jnp.broadcast_to(pos, (3, b, 1))
    else:
        positions = jnp.broadcast_to(pos, (b, 1))

    has_kv = "k" in cache
    if has_kv:
        w = cache["k"].shape[2]
        slot = pos % w
        n_valid = jnp.minimum(pos + 1, w)
        kv_valid = jnp.broadcast_to(jnp.arange(w)[None, :] < n_valid, (b, w))

    def body(x, xs):
        blk = xs["blk"]
        ys = {}
        h = rms_norm(x, blk["ln1"])
        if kind in ("dense", "moe", "hybrid", "dec"):
            q, k, v = _qkv(blk["attn"], cfg, h, positions)
            ck = jax.lax.dynamic_update_slice_in_dim(
                xs["k"], k.astype(xs["k"].dtype), slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                xs["v"], v.astype(xs["v"].dtype), slot, axis=1)
            ys["k"], ys["v"] = ck, cv
            o = attn.attend(q, ck, cv,
                            q_pos=jnp.full((1,), pos, jnp.int32),
                            kv_pos=jnp.zeros((w,), jnp.int32),
                            causal=False, window=0, kv_valid=kv_valid)
            o = jnp.einsum("bsf,fd->bsd", o.reshape(b, 1, -1),
                           blk["attn"]["wo"])
            if kind == "hybrid":
                o2, sc = ssm_mod.ssd_step(
                    blk["ssm"], h,
                    {"conv": xs["ssm_conv"], "state": xs["ssm_state"]}, cfg)
                ys["ssm_conv"], ys["ssm_state"] = sc["conv"], sc["state"]
                o = 0.5 * (o + o2)
            x = x + o
        else:  # pure ssm
            o, sc = ssm_mod.ssd_step(
                blk["ssm"], h,
                {"conv": xs["ssm_conv"], "state": xs["ssm_state"]}, cfg)
            ys["ssm_conv"], ys["ssm_state"] = sc["conv"], sc["state"]
            x = x + o
            return x, ys
        if kind == "dec":
            from repro.models.transformer import _cross_attn
            x = x + _cross_attn(blk["cross"], cfg,
                                rms_norm(x, blk["ln_cross"]),
                                (xs["cross_k"], xs["cross_v"]))
            ys["cross_k"], ys["cross_v"] = xs["cross_k"], xs["cross_v"]
        h2 = rms_norm(x, blk["ln2"])
        if kind == "moe":
            out, _ = moe_mod.moe_fwd(blk["moe"], h2, cfg)
            x = x + out
        else:
            x = x + mlp_fwd(blk["mlp"], h2)
        return x, ys

    xs = {"blk": params["blocks"]}
    for key in ("k", "v", "ssm_conv", "ssm_state", "cross_k", "cross_v"):
        if key in cache:
            xs[key] = cache[key]
    x, ys = jax.lax.scan(body, x, xs)

    x = rms_norm(x, params["final"]["norm"])
    head = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["final"]["head"])
    logits = jnp.einsum("bd,dv->bv", x[:, 0, :], head.astype(x.dtype))

    new_cache = dict(cache)
    new_cache["pos"] = pos + 1
    for key, val in ys.items():
        new_cache[key] = val
    return logits, new_cache
