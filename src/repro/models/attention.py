"""Attention substrate: GQA, RoPE / M-RoPE, chunked-flash, sliding window.

Design notes (TPU adaptation):
- ``attend`` is a single entry point. For short KV it issues one masked
  einsum (MXU-friendly); for long KV it runs an online-softmax scan over KV
  chunks (pure-JAX flash) so 32k-token prefill lowers with O(chunk) score
  memory instead of O(S²).
- GQA is computed in grouped layout (B, S, KV, G, hd) — no materialised
  head-repeat, which matters when kv_heads ≪ heads (e.g. qwen2-vl 12H/2KV).
- Sliding-window masking makes every full-attention architecture eligible
  for the ``long_500k`` decode shape via a ring-buffer KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ----------------------------------------------------------------------
# Rotary embeddings
# ----------------------------------------------------------------------
def _rope_angles(positions: jnp.ndarray, hd: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, hd//2) in float32."""
    half = hd // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, S, H, hd); positions: (B, S)."""
    b, s, h, hd = x.shape
    cos, sin = _rope_angles(positions, hd, theta)       # (B, S, hd/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray,
                sections: tuple[int, ...], theta: float):
    """Qwen2-VL multimodal RoPE. positions: (3, B, S) = (t, h, w) ids.

    The hd/2 rotary frequency slots are partitioned into ``sections``
    (Σ sections = hd//2); each section rotates by its own position stream.
    """
    b, s, h, hd = x.shape
    assert sum(sections) == hd // 2, (sections, hd)
    cos_parts, sin_parts = [], []
    half = hd // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    off = 0
    for axis, sec in enumerate(sections):
        ang = positions[axis].astype(jnp.float32)[..., None] * inv[off:off + sec]
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        off += sec
    cos = jnp.concatenate(cos_parts, axis=-1)[:, :, None, :]  # (B,S,1,hd/2)
    sin = jnp.concatenate(sin_parts, axis=-1)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def text_mrope_positions(batch: int, seq: int) -> jnp.ndarray:
    """Text-only M-RoPE positions: t = h = w = arange (matches HF)."""
    p = jnp.broadcast_to(jnp.arange(seq)[None, :], (batch, seq))
    return jnp.stack([p, p, p], axis=0)


# ----------------------------------------------------------------------
# Masked single-block attention (short KV path)
# ----------------------------------------------------------------------
def _mask_bias(q_pos, kv_pos, *, causal: bool, window: int,
               kv_valid: Optional[jnp.ndarray] = None):
    """Additive bias (..., Sq, Skv) from position constraints (float32)."""
    ok = jnp.ones(q_pos.shape[-1:] + kv_pos.shape[-1:], dtype=bool)
    if causal:
        ok &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= kv_pos[None, :] > q_pos[:, None] - window
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    if kv_valid is not None:  # (B, Skv) bool
        bias = bias[None] + jnp.where(kv_valid, 0.0, NEG_INF)[:, None, :]
    return bias


def _attend_block(q, k, v, bias):
    """q: (B,Sq,KV,G,hd); k,v: (B,Skv,KV,hd); bias: (B?,Sq,Skv) fp32."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bckd->bkgqc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if bias.ndim == 2:
        bias = bias[None]
    s = s + bias[:, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p, v.astype(jnp.float32))
    return o


# ----------------------------------------------------------------------
# Chunked-flash attention (long KV path)
# ----------------------------------------------------------------------
def _attend_flash(q, k, v, q_pos, kv_pos, *, causal, window, chunk,
                  kv_valid=None, probs_bf16=False):
    b, sq, kvh, g, hd = q.shape
    skv = k.shape[1]
    nchunks = -(-skv // chunk)
    pad = nchunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=2**30)
        if kv_valid is not None:
            kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)))
    if kv_valid is None:
        kv_valid = jnp.ones((b, nchunks * chunk), dtype=bool)
    kv_valid &= kv_pos[None, :] < 2**30

    kc = k.reshape(b, nchunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(nchunks, chunk)
    valc = kv_valid.reshape(b, nchunks, chunk).transpose(1, 0, 2)

    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qf = q.astype(jnp.float32)

    def body(carry, xs):
        m, l, o = carry
        kb, vb, pb, valb = xs
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kb.astype(jnp.float32)) * scale
        ok = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            ok &= pb[None, :] <= q_pos[:, None]
        if window > 0:
            ok &= pb[None, :] > q_pos[:, None] - window
        bias = jnp.where(ok, 0.0, NEG_INF)
        bias = bias[None] + jnp.where(valb, 0.0, NEG_INF)[:, None, :]
        s = s + bias[:, None, None, :, :]
        m_new = jnp.maximum(m, s.max(axis=-1))
        # Guard fully-masked blocks: with m == s == NEG_INF, exp(s - m) would
        # be exp(0) = 1; force those probabilities (and the correction) to 0/1
        # explicitly.
        corr = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp(m - m_new))
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[..., None]))
        l_new = l * corr + p.sum(axis=-1)
        if probs_bf16:
            # §Perf lever: the probability tensor dominates flash HBM
            # traffic under XLA lowering; bf16 halves it (fp32 accumulate).
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(jnp.bfloat16),
                            vb.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p, vb.astype(jnp.float32))
        o_new = o * corr[..., None] + pv
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), dtype=jnp.float32)
    o0 = jnp.zeros((b, kvh, g, sq, hd), dtype=jnp.float32)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (kc, vc, pc, valc))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.transpose(0, 3, 1, 2, 4)  # (B,Sq,KV,G,hd)


# ----------------------------------------------------------------------
# Public entry point
# ----------------------------------------------------------------------
def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
           q_pos: jnp.ndarray, kv_pos: jnp.ndarray,
           causal: bool = True, window: int = 0,
           kv_valid: Optional[jnp.ndarray] = None,
           chunk: int = 1024, flash_threshold: int = 2048,
           probs_bf16: bool = False) -> jnp.ndarray:
    """Grouped-query attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd); H = KV·G.
    q_pos: (Sq,) absolute positions of queries; kv_pos: (Skv,).
    kv_valid: optional (B, Skv) bool (cache occupancy for decode).
    Returns (B, Sq, H, hd) in q.dtype.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    skv = k.shape[1]
    if skv <= flash_threshold:
        bias = _mask_bias(q_pos, kv_pos, causal=causal, window=window,
                          kv_valid=kv_valid)
        o = _attend_block(qg, k, v, bias)
    else:
        o = _attend_flash(qg, k, v, q_pos, kv_pos, causal=causal,
                          window=window, chunk=chunk, kv_valid=kv_valid,
                          probs_bf16=probs_bf16)
    return o.reshape(b, sq, h, hd).astype(q.dtype)
