"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked SSD: within a chunk of length Q the recurrence is computed in its
quadratic "attention-like" dual form (MXU-friendly einsums); across chunks a
linear recurrence carries the (H, N, P) state. Decode is the O(1) recurrent
update — this is what makes the ``long_500k`` shape natural for SSM/hybrid
architectures (constant state instead of a 524k-entry KV cache).

Layout: G = 1 B/C group (Mamba-2 default "multi-value attention" analogue);
heads H = expand·d_model / head_dim P; state size N per head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, dtype_of
from repro.models.layers import init_dense, lora_dense, rms_norm


def init_ssm(key, cfg: ModelConfig):
    dt = dtype_of(cfg.param_dtype)
    d, di, n, h, w = (cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_conv_width)
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * n
    return {
        "in_proj": init_dense(ks[0], d, 2 * di + 2 * n + h, dt),
        "conv_w": (jax.random.normal(ks[1], (w, conv_ch)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((h,), jnp.float32),           # A = -exp(A_log) = -1
        "D_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),    # softplus ~0.12
        "norm_scale": jnp.ones((di,), dt),
        "out_proj": init_dense(ks[2], di, d, dt),
    }


def _split_proj(p, x, cfg: ModelConfig):
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = lora_dense(x, p["in_proj"], p.get("lora"), "in_proj")
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt_raw


def _causal_conv(p, xbc, cfg: ModelConfig):
    """Depthwise causal conv over (B, S, C') channels."""
    w = cfg.ssm_conv_width
    kernel = p["conv_w"][:, None, :]                     # (W, 1, C')
    out = jax.lax.conv_general_dilated(
        xbc, kernel.astype(xbc.dtype),
        window_strides=(1,), padding=[(w - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xbc.shape[-1])
    return jax.nn.silu((out + p["conv_b"]).astype(jnp.float32)).astype(xbc.dtype)


def ssd_fwd(p, xin: jnp.ndarray, cfg: ModelConfig,
            return_cache: bool = False):
    """Full-sequence chunked SSD. xin: (B, S, D) -> (B, S, D)[, cache]."""
    bsz, s, _ = xin.shape
    di, n, h, pdim, q = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads,
                         cfg.ssm_head_dim, cfg.ssm_chunk)
    nc = -(-s // q)
    pad = nc * q - s

    z, xbc_raw, dt_raw = _split_proj(p, xin, cfg)
    xbc = _causal_conv(p, xbc_raw, cfg)
    x, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)   # (B,S,di/n/n)

    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt_raw = jnp.pad(dt_raw, ((0, 0), (0, pad), (0, 0)))

    xh = x.reshape(bsz, nc, q, h, pdim).astype(jnp.float32)
    bc = bmat.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(bsz, nc, q, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.reshape(bsz, nc, q, h).astype(jnp.float32)
                         + p["dt_bias"])
    if pad:
        # Padded positions must not decay the state: force dt -> 0 there.
        valid = (jnp.arange(nc * q) < s).reshape(1, nc, q, 1)
        dt = dt * valid
    a = -jnp.exp(p["A_log"])                                 # (H,)
    da = dt * a                                              # (B,NC,Q,H)
    cum = jnp.cumsum(da, axis=2)                             # (B,NC,Q,H)

    # Intra-chunk (dual quadratic form).
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)               # (B,NC,Q,Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    causal = jnp.tril(jnp.ones((q, q), dtype=bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    y_intra = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                         cb, decay, dt, xh)

    # Chunk summaries -> inter-chunk recurrence.
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)             # (B,NC,Q,H)
    s_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                         decay_end * dt, bc, xh)             # (B,NC,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,NC,H)

    def body(state, xs):
        sc, cd = xs                                          # (B,H,N,P),(B,H)
        y_state = state                                      # state BEFORE chunk
        state = cd[..., None, None] * state + sc
        return state, y_state

    s_t = s_chunk.transpose(1, 0, 2, 3, 4)                   # (NC,B,H,N,P)
    cd_t = chunk_decay.transpose(1, 0, 2)                    # (NC,B,H)
    state0 = jnp.zeros((bsz, h, n, pdim), jnp.float32)
    final_state, states = jax.lax.scan(body, state0, (s_t, cd_t))
    states = states.transpose(1, 0, 2, 3, 4)                 # (B,NC,H,N,P)

    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp",
                         cc, states, jnp.exp(cum))
    y = y_intra + y_inter + p["D_skip"][None, None, None, :, None] * xh
    y = y.reshape(bsz, nc * q, di)[:, :s]

    z = z.astype(jnp.float32)
    y = rms_norm((y * jax.nn.silu(z)).astype(xin.dtype), p["norm_scale"])
    out = lora_dense(y, p["out_proj"].astype(y.dtype), p.get("lora"),
                     "out_proj")
    if not return_cache:
        return out
    # Recurrent cache: final SSM state + raw (pre-conv) xbc tail.
    w = cfg.ssm_conv_width
    tail = xbc_raw[:, -(w - 1):, :]
    if s < w - 1:
        tail = jnp.pad(xbc_raw, ((0, 0), (w - 1 - s, 0), (0, 0)))
    return out, {"conv": tail, "state": final_state}


# ----------------------------------------------------------------------
# Decode (recurrent) path
# ----------------------------------------------------------------------
def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """Per-layer recurrent cache: conv tail + SSM state."""
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1,
                           cfg.ssm_d_inner + 2 * cfg.ssm_state), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                            cfg.ssm_head_dim), jnp.float32),
    }


def ssd_step(p, xin: jnp.ndarray, cache: dict, cfg: ModelConfig):
    """Single-token recurrent update. xin: (B, 1, D) -> (B, 1, D), cache'."""
    bsz = xin.shape[0]
    di, n, h, pdim = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads,
                      cfg.ssm_head_dim)
    z, xbc, dt_raw = _split_proj(p, xin[:, 0, :], cfg)       # (B, ...)

    # conv with cached tail
    hist = jnp.concatenate([cache["conv"],
                            xbc[:, None, :].astype(cache["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    xbc_act = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:, :]

    x, bvec, cvec = jnp.split(xbc_act, [di, di + n], axis=-1)
    xh = x.reshape(bsz, h, pdim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a)                                     # (B,H)

    state = (da[..., None, None] * cache["state"]
             + jnp.einsum("bh,bn,bhp->bhnp", dt, bvec, xh))
    y = jnp.einsum("bn,bhnp->bhp", cvec, state)
    y = y + p["D_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, di)

    z = jax.nn.silu(z.astype(jnp.float32))[:, None, :]
    y = rms_norm((y * z).astype(xin.dtype), p["norm_scale"])
    out = lora_dense(y, p["out_proj"].astype(y.dtype), p.get("lora"),
                     "out_proj")
    return out, {"conv": new_conv, "state": state}
