"""Participant sampling (Algorithm 1 line 5: C_t ← random(K, max(C·N, 1)))."""
from __future__ import annotations

import numpy as np


def sample_clients(rng: np.random.Generator, num_clients: int,
                   k: int) -> np.ndarray:
    """Uniformly sample K distinct participants for this round."""
    k = max(1, min(k, num_clients))
    return rng.choice(num_clients, size=k, replace=False)
