"""Participant sampling (Algorithm 1 line 5: C_t ← random(K, max(C·N, 1))).

Two interchangeable samplers:

- :func:`sample_clients` — host-side ``numpy`` sampling (the original
  reference driver path; one host RNG draw per round).
- :func:`sample_clients_jax` — pure-JAX sampling, jit/scan-safe, used by the
  device-resident multi-round engine (``run_training_scan``) and by
  ``run_training(sampler="jax")`` so the two drivers see *identical*
  participant sets for a given seed.

- :func:`sample_clients_grouped` — per-affinity-group sampling for
  sample-sharded datasets (``ClientShards.place(shard_samples=True)``): the
  K-cohort is drawn ``K/G`` per contiguous client group so the positional
  device split matches data placement.

:func:`round_keys` defines the per-round key schedule shared by both JAX
paths: one fold_in per round, split into (client, batch, algorithm) streams.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample_clients(rng: np.random.Generator, num_clients: int,
                   k: int) -> np.ndarray:
    """Uniformly sample K distinct participants for this round (host RNG)."""
    k = max(1, min(k, num_clients))
    return rng.choice(num_clients, size=k, replace=False)


def sample_clients_jax(key: jax.Array, num_clients: int,
                       k: int) -> jnp.ndarray:
    """Uniformly sample K distinct participants on device (jit/scan-safe).

    Deterministic in ``key``; shapes are static so this traces cleanly
    inside ``lax.scan`` over rounds.
    """
    k = max(1, min(k, num_clients))
    return jax.random.choice(key, num_clients, shape=(k,), replace=False)


def sample_clients_grouped(key: jax.Array, num_clients: int, k: int,
                           num_groups: int) -> jnp.ndarray:
    """Per-affinity-group participant sampling (jit/scan-safe).

    With sample-axis sharding
    (:meth:`repro.data.ClientShards.place` ``shard_samples=True``) group
    ``g`` — i.e. device ``g`` of the 'clients' mesh axis — holds exactly
    the samples of clients ``[g·N/G, (g+1)·N/G)``. The cohort must respect
    that placement: this draws ``k/G`` distinct clients from each group's
    contiguous range and concatenates in group order, so the sharded
    round's positional row split (:func:`local_rows`: device ``i`` owns
    rows ``[i·K/D, (i+1)·K/D)``) hands every device only clients whose
    data is device-local — the round-batch gather never crosses devices.

    Deterministic in ``key`` (one ``fold_in`` per group);
    ``num_groups=1`` degenerates to :func:`sample_clients_jax` exactly, so
    ungrouped shards keep their bit-identical trajectories.
    """
    if num_groups <= 1:
        return sample_clients_jax(key, num_clients, k)
    if num_clients % num_groups or k % num_groups:
        raise ValueError(
            f"sample_clients_grouped: N={num_clients} and K={k} must both "
            f"divide into {num_groups} affinity groups")
    cpg, kpg = num_clients // num_groups, k // num_groups
    draws = [jax.random.choice(jax.random.fold_in(key, g), cpg,
                               shape=(kpg,), replace=False) + g * cpg
             for g in range(num_groups)]
    return jnp.concatenate(draws)


def local_rows(arr: jnp.ndarray, axis_name: str, shard_size: int
               ) -> jnp.ndarray:
    """This device's contiguous row block of a replicated, participant-
    indexed array (inside ``shard_map``).

    The client-sharded round keeps sampling *replicated* — every device
    computes the same K participants from the same key — and splits the
    round by position: device i owns rows [i·K/D, (i+1)·K/D). ``arr`` is any
    (K, ...) array aligned with the participant order (selection matrix,
    divergence rows, client ids); the result is this device's (K/D, ...)
    block, matching how P('clients') in_specs split the stacked batch.
    """
    row0 = jax.lax.axis_index(axis_name) * shard_size
    return jax.lax.dynamic_slice_in_dim(arr, row0, shard_size, axis=0)


def round_keys(base_key: jax.Array, t) -> tuple[jax.Array, jax.Array,
                                                jax.Array]:
    """Per-round (client_key, batch_key, algo_key) streams.

    ``t`` may be a Python int (host driver) or a traced scalar (scan engine);
    both produce the same keys for the same round index.
    """
    k = jax.random.fold_in(base_key, t)
    ck, bk, ak = jax.random.split(k, 3)
    return ck, bk, ak
