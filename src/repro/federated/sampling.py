"""Participant sampling (Algorithm 1 line 5: C_t ← random(K, max(C·N, 1))).

Two interchangeable samplers:

- :func:`sample_clients` — host-side ``numpy`` sampling (the original
  reference driver path; one host RNG draw per round).
- :func:`sample_clients_jax` — pure-JAX sampling, jit/scan-safe, used by the
  device-resident multi-round engine (``run_training_scan``) and by
  ``run_training(sampler="jax")`` so the two drivers see *identical*
  participant sets for a given seed.

:func:`round_keys` defines the per-round key schedule shared by both JAX
paths: one fold_in per round, split into (client, batch, algorithm) streams.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample_clients(rng: np.random.Generator, num_clients: int,
                   k: int) -> np.ndarray:
    """Uniformly sample K distinct participants for this round (host RNG)."""
    k = max(1, min(k, num_clients))
    return rng.choice(num_clients, size=k, replace=False)


def sample_clients_jax(key: jax.Array, num_clients: int,
                       k: int) -> jnp.ndarray:
    """Uniformly sample K distinct participants on device (jit/scan-safe).

    Deterministic in ``key``; shapes are static so this traces cleanly
    inside ``lax.scan`` over rounds.
    """
    k = max(1, min(k, num_clients))
    return jax.random.choice(key, num_clients, shape=(k,), replace=False)


def local_rows(arr: jnp.ndarray, axis_name: str, shard_size: int
               ) -> jnp.ndarray:
    """This device's contiguous row block of a replicated, participant-
    indexed array (inside ``shard_map``).

    The client-sharded round keeps sampling *replicated* — every device
    computes the same K participants from the same key — and splits the
    round by position: device i owns rows [i·K/D, (i+1)·K/D). ``arr`` is any
    (K, ...) array aligned with the participant order (selection matrix,
    divergence rows, client ids); the result is this device's (K/D, ...)
    block, matching how P('clients') in_specs split the stacked batch.
    """
    row0 = jax.lax.axis_index(axis_name) * shard_size
    return jax.lax.dynamic_slice_in_dim(arr, row0, shard_size, axis=0)


def round_keys(base_key: jax.Array, t) -> tuple[jax.Array, jax.Array,
                                                jax.Array]:
    """Per-round (client_key, batch_key, algo_key) streams.

    ``t`` may be a Python int (host driver) or a traced scalar (scan engine);
    both produce the same keys for the same round index.
    """
    k = jax.random.fold_in(base_key, t)
    ck, bk, ak = jax.random.split(k, 3)
    return ck, bk, ak
