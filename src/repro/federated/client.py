"""ClientUpdate (paper Algorithm 1, lines 11-15).

A client receives the global model, runs ``local_steps`` optimizer steps on
its local batch (the paper uses exactly one SGD step — "after one time local
training"), and returns its local model. The update is a *pure deterministic*
function of (global params, client batch) — the property that enables the
two-phase recompute execution mode for large models (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import sgd
from repro.optim.opt import Optimizer

Pytree = Any
LossFn = Callable[[Pytree, dict], jnp.ndarray]


def make_local_update(loss_fn: LossFn, opt: Optimizer,
                      local_steps: int = 1, remat: bool = False):
    """Returns local_update(global_params, batch) -> (local_params, mean_loss).

    ``batch`` leaves are (b, ...) — the same batch is used for every local
    step (paper setting: local_steps=1 makes this exact; >1 approximates
    multi-epoch local training on the client's sampled data).

    ``remat=True`` wraps each local step in ``jax.checkpoint`` so forward
    activations are recomputed in the backward pass — useful when the whole
    FL schedule is one ``lax.scan`` (run_training_scan) and K stacked
    clients × local activations would otherwise set the peak-memory
    high-water mark.
    """

    def local_update(global_params: Pytree, batch: dict):
        ostate0 = opt.init(global_params)

        def step(carry, _):
            params, ostate = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, ostate = opt.update(grads, ostate, params)
            return (params, ostate), loss

        if remat:
            step = jax.checkpoint(step)
        (params, _), losses = jax.lax.scan(
            step, (global_params, ostate0), None, length=local_steps)
        return params, losses.mean()

    return local_update


def plain_sgd_client(loss_fn: LossFn, lr: float, local_steps: int = 1):
    """The paper's exact ClientUpdate: Θ_k ← Θ − η∇F_k(Θ)."""
    return make_local_update(loss_fn, sgd(lr), local_steps)
