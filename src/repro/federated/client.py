"""ClientUpdate (paper Algorithm 1, lines 11-15).

A client receives the global model, runs ``local_steps`` optimizer steps on
its local batch (the paper uses exactly one SGD step — "after one time local
training"), and returns its local model. The update is a *pure deterministic*
function of (global params, client batch) — the property that enables the
two-phase recompute execution mode for large models (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.partition import ParamPartition
from repro.optim import sgd
from repro.optim.opt import Optimizer

Pytree = Any
LossFn = Callable[[Pytree, dict], jnp.ndarray]


def make_local_update(loss_fn: LossFn, opt: Optimizer,
                      local_steps: int = 1, remat: bool = False,
                      partition: Optional[ParamPartition] = None):
    """Returns local_update(global_params, batch) -> (local_params, mean_loss).

    ``batch`` leaves are (b, ...) — the same batch is used for every local
    step (paper setting: local_steps=1 makes this exact; >1 approximates
    multi-epoch local training on the client's sampled data).

    ``remat=True`` wraps each local step in ``jax.checkpoint`` so forward
    activations are recomputed in the backward pass — useful when the whole
    FL schedule is one ``lax.scan`` (run_training_scan) and K stacked
    clients × local activations would otherwise set the peak-memory
    high-water mark.

    With a :class:`~repro.core.partition.ParamPartition`, the returned
    function is ``local_update(trainable, batch, frozen) ->
    (local_trainable, mean_loss)``: the loss sees the merged full model,
    but gradients, optimizer state, and the returned local model cover the
    trainable sub-pytree only — the frozen base is a closed-over constant
    of the round, exactly the adapter fine-tuning contract.
    """
    if partition is not None:
        def local_update_part(trainable: Pytree, batch: dict,
                              frozen: Pytree):
            ostate0 = opt.init(trainable)

            def step(carry, _):
                train, ostate = carry
                loss, grads = jax.value_and_grad(
                    lambda tr: loss_fn(partition.merge(tr, frozen),
                                       batch))(train)
                train, ostate = opt.update(grads, ostate, train)
                return (train, ostate), loss

            if remat:
                step = jax.checkpoint(step)
            (train, _), losses = jax.lax.scan(
                step, (trainable, ostate0), None, length=local_steps)
            return train, losses.mean()

        return local_update_part

    def local_update(global_params: Pytree, batch: dict):
        ostate0 = opt.init(global_params)

        def step(carry, _):
            params, ostate = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, ostate = opt.update(grads, ostate, params)
            return (params, ostate), loss

        if remat:
            step = jax.checkpoint(step)
        (params, _), losses = jax.lax.scan(
            step, (global_params, ostate0), None, length=local_steps)
        return params, losses.mean()

    return local_update


def plain_sgd_client(loss_fn: LossFn, lr: float, local_steps: int = 1):
    """The paper's exact ClientUpdate: Θ_k ← Θ − η∇F_k(Θ)."""
    return make_local_update(loss_fn, sgd(lr), local_steps)
