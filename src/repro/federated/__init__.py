"""Federated runtime: ClientUpdate + ServerExecute (Algorithm 1)."""
from repro.federated.client import make_local_update, plain_sgd_client
from repro.federated.sampling import sample_clients
from repro.federated.server import (ALGOS, FLConfig, TrainLog,
                                    build_round_fn, build_round_scan,
                                    build_round_vmap, run_training)

__all__ = ["make_local_update", "plain_sgd_client", "sample_clients",
           "ALGOS", "FLConfig", "TrainLog", "build_round_fn",
           "build_round_scan", "build_round_vmap", "run_training"]
