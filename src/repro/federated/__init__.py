"""Federated runtime: ClientUpdate + ServerExecute (Algorithm 1)."""
from repro.federated.client import make_local_update, plain_sgd_client
from repro.federated.sampling import (local_rows, round_keys, sample_clients,
                                      sample_clients_jax)
from repro.federated.server import (ALGOS, FLConfig, TrainLog,
                                    build_round_fn, build_round_scan,
                                    build_round_vmap, init_residual_store,
                                    residual_store_specs, run_training,
                                    run_training_scan)

__all__ = ["make_local_update", "plain_sgd_client", "local_rows",
           "round_keys", "sample_clients", "sample_clients_jax", "ALGOS",
           "FLConfig", "TrainLog", "build_round_fn", "build_round_scan",
           "build_round_vmap", "init_residual_store",
           "residual_store_specs", "run_training", "run_training_scan"]
