"""Federated runtime: ClientUpdate + ServerExecute (Algorithm 1).

Algorithms are strategy plugins — see :mod:`repro.federated.strategies`.
``ALGOS`` is a live view of the registry (module ``__getattr__``), so
``register_strategy`` additions appear here automatically.
"""
from repro.federated.client import make_local_update, plain_sgd_client
from repro.federated.sampling import (local_rows, round_keys, sample_clients,
                                      sample_clients_jax)
from repro.federated.server import (FLConfig, TrainLog, build_round_fn,
                                    build_round_scan, build_round_vmap,
                                    run_training, run_training_scan)
# the residual-store helpers moved to launch/sharding (they are state-seam
# placement policy, not server plumbing); re-exported here for compat
from repro.launch.sharding import init_residual_store, residual_store_specs
from repro.federated.strategies import (FedADPOptions, FedLAMAOptions,
                                        FedLPOptions, FLStrategy,
                                        QuantizedUpload, make_strategy,
                                        register_strategy, registered_algos,
                                        strategy_registry,
                                        unregister_strategy)
# the wire-format config rides FLConfig(compression=...); re-exported so
# FL callers need one import (full wire format: repro.core.wire)
from repro.core.wire import CompressionConfig
# the trainable/frozen split rides FLConfig(partition=...); re-exported so
# adapter fine-tuning callers need one import (full module:
# repro.core.partition)
from repro.core.partition import ParamPartition
# observability config rides FLConfig(telemetry=...); re-exported so FL
# callers need one import (full subsystem: repro.telemetry)
from repro.telemetry import TelemetryConfig

__all__ = ["make_local_update", "plain_sgd_client", "local_rows",
           "round_keys", "sample_clients", "sample_clients_jax", "ALGOS",
           "CompressionConfig", "FLConfig", "FLStrategy", "FedADPOptions",
           "FedLAMAOptions", "FedLPOptions", "ParamPartition",
           "QuantizedUpload",
           "TelemetryConfig", "TrainLog",
           "build_round_fn", "build_round_scan", "build_round_vmap",
           "init_residual_store", "make_strategy", "register_strategy",
           "registered_algos", "residual_store_specs", "run_training",
           "run_training_scan", "strategy_registry", "unregister_strategy"]


def __getattr__(name):   # PEP 562: ALGOS tracks the live strategy registry
    if name == "ALGOS":
        return registered_algos()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
