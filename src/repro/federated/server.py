"""ServerExecute (paper Algorithm 1) — round function builders + drivers.

Two per-round execution modes produce identical aggregation semantics
(tested):

- ``vmap``: all K clients train in parallel (client axis shardable over the
  'data' mesh axis) and their models are materialised stacked — the paper's
  own regime (small models, many clients).
- ``scan``: clients run sequentially over the whole mesh; FedLDF divergence
  feedback needs all K divergence vectors *before* deciding what to
  aggregate, so the round runs two passes of deterministic local training
  (phase 1: divergence only; phase 2: accumulate selected layers). This is
  protocol-level rematerialization — O(1)-client memory for LLM-scale FL.

Two *multi-round* drivers share those round functions:

- :func:`run_training` — the host-loop reference oracle: one Python
  iteration per round (host RNG or JAX-RNG sampling, per-round
  host↔device batch transfer, per-round ``CommMeter`` pulls).
- :func:`run_training_scan` — the device-resident engine: the whole FL
  schedule is one jitted ``jax.lax.scan`` over rounds. Client sampling is
  ``jax.random.choice`` on device, round batches are gathered from
  device-resident :class:`~repro.data.ClientShards`, communication totals
  accumulate in the scan carry (one device→host pull per eval block), the
  carry buffers (params, error-feedback residuals, comm accumulator) are
  donated between blocks, and error-feedback residuals are threaded
  through rounds via a per-client store — ``run_training(sampler="jax")``
  and ``run_training_scan`` produce identical trajectories for the same
  seed (tested to fp32 tolerance; see benchmarks/round_engine_bench.py for
  the rounds/sec comparison).

Both drivers scale past one accelerator via mesh sharding: with
``FLConfig(mesh=make_client_mesh(...))`` the vmap round runs under
``shard_map`` over the mesh's 'clients' axis — each device trains K/D
clients, FedLDF's divergence matrix is all-gathered for the global top-n
selection, and the Eq. 5 aggregation / comm totals are psum-reduced, so the
new global model comes back replicated. A 2-D
``make_client_mesh(D, model=M)`` mesh additionally FSDP-shards the memory
that used to be replicated per device: every parameter leaf and every row
of the error-feedback residual store (the first memory cliff, at N × model
size) lives as a 1/M 'model'-axis shard
(:func:`repro.launch.sharding.fl_param_specs`); the round transiently
all-gathers the full model for local training and slices the aggregation
back to shards before the clients-axis psum. ``mesh=None`` (default) is the
original single-device path, byte-for-byte unchanged, and 1-D client meshes
are unchanged too. Sharded and unsharded trajectories agree to fp32
tolerance on a fixed seed (the reduction order differs;
tests/test_shard_engine.py and tests/test_model_axis.py pin this down).

Algorithms are **strategy plugins** (:mod:`repro.federated.strategies`):
the engines above are thin execution shells around the jit-safe
:class:`~repro.federated.strategies.FLStrategy` hooks (``select``,
``transform_upload``, ``aggregate``, ``comm_profile``, …), and
``FLConfig.algo`` resolves through the strategy registry — built-ins are
fedldf (paper), fedavg (Eq. 1), random (per-layer random-n), hdfl (client
dropout [7]), fedadp (neuron pruning [6]), fedlp (layer-wise probabilistic
pruning, arXiv:2303.06360); ``register_strategy`` adds user-defined
schemes without touching this module. Per-strategy capability flags
(``supports_scan`` / ``supports_mesh`` / ``supports_quantize``) replace
engine-side special cases and are validated at ``FLConfig`` construction.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import aggregation as agg
from repro.core import comm as comm_mod
from repro.core.partition import ParamPartition, partition_counts
from repro.core.units import UnitMap
from repro.core.wire import CompressionConfig
from repro.data.device import ClientShards
from repro.federated.client import make_local_update
from repro.federated.sampling import (local_rows, round_keys, sample_clients,
                                      sample_clients_grouped,
                                      sample_clients_jax)
from repro.federated.strategies import (FedADPOptions, FedLAMAOptions,
                                        FedLPOptions, get_strategy_cls,
                                        make_strategy, registered_algos)
from repro.launch.mesh import (CLIENT_AXIS, MODEL_AXIS, client_mesh_size,
                               model_mesh_size, replicated_rng,
                               shard_map_norep)
from repro.launch.sharding import (fl_param_specs, to_named,
                                   tree_all_gather, tree_shard_slice)
from repro.optim import sgd
from repro.optim.opt import Optimizer
from repro.telemetry import ProgressSink, RoundLedger, TelemetryConfig
from repro.telemetry import profiling as prof_mod
from repro.telemetry import taps as taps_mod

Pytree = Any


def __getattr__(name):   # PEP 562: ALGOS is a live view of the registry
    if name == "ALGOS":
        return registered_algos()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# deprecated flat FLConfig fields → (owning algo, options field); the
# normalization shim in FLConfig.__post_init__ folds non-default values
# into algo_options and mirrors the normalized options back, so old
# readers of the flat names keep seeing the effective values.
_DEPRECATED_ALGO_FIELDS = (
    ("fedadp_keep", "fedadp", "keep"),
    ("fedlp_p", "fedlp", "p"),
    ("fedlama_tau", "fedlama", "tau"),
    ("fedlama_lam", "fedlama", "lam"),
)

# Raised when compression=CompressionConfig(...) meets the sequential-client
# scan engine (asserted verbatim in tests/test_wire.py — keep in sync).
_SCAN_COMPRESSION_MSG = (
    "compression=CompressionConfig(...) is not supported by the "
    "sequential-client scan engine (mode='scan'): the packed quantized "
    "uplink reduces a stacked client axis. Supported drivers: mode='vmap' "
    "on a single device, the mesh-sharded round (FLConfig(mesh=...)), and "
    "both multi-round drivers (run_training / run_training_scan) on top of "
    "them.")


@dataclasses.dataclass(frozen=True)
class FLConfig:
    algo: str = "fedldf"
    num_clients: int = 50          # N
    clients_per_round: int = 20    # K
    top_n: int = 4                 # n (per-layer uploads)
    local_steps: int = 1
    lr: float = 0.05
    mode: str = "vmap"             # vmap | scan
    # per-strategy knobs: FedADPOptions | FedLPOptions | FedLAMAOptions |
    # a plugin strategy's declared options_cls. None resolves to the
    # strategy's defaults (or to the deprecated flat fields below).
    algo_options: Optional[Any] = None
    # uplink compression policy (repro.core.wire.CompressionConfig):
    # packed wire-format quantized uploads + optional error feedback +
    # divergence-driven bit allocation (bits="auto"). None = fp32 uploads.
    compression: Optional[CompressionConfig] = None
    # trainable/frozen split (repro.core.partition.ParamPartition): only
    # the trainable sub-pytree is trained, divergence-scored, communicated,
    # and aggregated; the frozen base stays device-resident and is closed
    # over by local training (adapter fine-tuning). None = every leaf
    # trainable, bit-identical to the pre-partition engine.
    partition: Optional[ParamPartition] = None
    batch_per_client: int = 32
    # remat local-training steps (jax.checkpoint): caps activation memory
    # when K stacked clients run inside the scan engine
    remat: bool = False
    # ---- deprecated flat knobs (warn + fold into algo_options /
    # compression; kept as mirrors of the normalized values) ----
    fedadp_keep: float = 0.2       # FedADP keep fraction (equal-comm setting)
    fedlp_p: float = 0.5           # FedLP per-layer keep probability
    fedlama_tau: int = 2           # FedLAMA base aggregation interval τ'
    fedlama_lam: int = 2           # FedLAMA long-interval multiplier λ
    quantize_bits: int = 0         # quantized delta upload (0 = off)
    error_feedback: bool = False
    # multi-device: shard the stacked client axis over this mesh's 'clients'
    # axis; a 2-D ('clients', 'model') mesh (make_client_mesh(model=M))
    # additionally FSDP-shards param leaves + the EF residual store 1/M per
    # device. None = single-device round, unchanged.
    mesh: Optional[Mesh] = None
    # hierarchical two-tier aggregation (mesh only): the round's fused
    # reduce becomes a group-local psum over blocks of agg_group_size
    # consecutive 'clients'-axis devices followed by a ring all-reduce
    # across group leaders (lax.ppermute rotations; see
    # repro.core.aggregation.hierarchical_psum). 0 (default) keeps the
    # single flat psum — the compiled round is byte-identical to the
    # pre-tier engine. 1 = pure ring all-reduce over all devices.
    agg_group_size: int = 0
    # sample-axis sharding (mesh only): the drivers place ClientShards
    # with shard_samples=True — samples are permuted into per-device
    # blocks by the static client→device affinity, the cohort is drawn
    # per affinity group, and the round-batch gather reads device-local
    # rows only. At-rest dataset bytes/device drop ~1/D.
    shard_samples: bool = False
    # observability: in-jit metric taps + JSONL round ledger + profiling
    # hooks (see repro.telemetry). None (default) is the zero-cost path:
    # compiled rounds, scan carries, and fixed-seed trajectories are
    # bit-identical to a config without telemetry.
    telemetry: Optional[TelemetryConfig] = None

    # ------------------------------------------------------------------
    def _normalize_algo_options(self, scls):
        """Fold the deprecated flat per-algo knobs into ``algo_options``
        (validating through the owning options classes) and mirror the
        normalized options back onto the flat names, so equivalent
        spellings compare (and jit-cache) equal."""
        defaults = {f.name: f.default
                    for f in dataclasses.fields(type(self))}
        flat_set = [name for name, _, _ in _DEPRECATED_ALGO_FIELDS
                    if getattr(self, name) != defaults[name]]
        # validation of the flat values is unconditional (as it was when
        # FLConfig owned these checks), algo match or not: constructing
        # the options classes raises ValueError on bad values.
        legacy = {
            "fedadp": FedADPOptions(keep=self.fedadp_keep),
            "fedlp": FedLPOptions(p=self.fedlp_p),
            "fedlama": FedLAMAOptions(tau=self.fedlama_tau,
                                      lam=self.fedlama_lam),
        }
        opts = self.algo_options
        if opts is not None:
            ocls = getattr(scls, "options_cls", None)
            if ocls is None:
                raise TypeError(
                    f"strategy {self.algo!r} declares no options class; "
                    f"got algo_options={opts!r}")
            if not isinstance(opts, ocls):
                raise TypeError(
                    f"algo_options for strategy {self.algo!r} must be "
                    f"{ocls.__name__}, got {type(opts).__name__}")
            # a flat field that disagrees with the options instance is a
            # conflict; agreeing values (the mirrors dataclasses.replace
            # round-trips) are fine.
            for name, algo, field in _DEPRECATED_ALGO_FIELDS:
                if algo != self.algo or name not in flat_set:
                    continue
                if getattr(self, name) != getattr(opts, field):
                    raise ValueError(
                        f"FLConfig.{name}={getattr(self, name)} conflicts "
                        f"with algo_options.{field}="
                        f"{getattr(opts, field)}; pass one spelling, "
                        "not both")
        else:
            if flat_set:
                warnings.warn(
                    f"FLConfig fields {flat_set} are deprecated; pass "
                    "algo_options=FedADPOptions/FedLPOptions/"
                    "FedLAMAOptions(...) instead",
                    DeprecationWarning, stacklevel=3)
            opts = legacy.get(self.algo)
            if opts is None and getattr(scls, "options_cls", None):
                opts = scls.options_cls()
            object.__setattr__(self, "algo_options", opts)
        # mirror the normalized options back onto the flat names
        for name, algo, field in _DEPRECATED_ALGO_FIELDS:
            if algo == self.algo and opts is not None:
                object.__setattr__(self, name, getattr(opts, field))

    def _normalize_compression(self, scls):
        """Fold the deprecated ``quantize_bits``/``error_feedback`` flats
        into ``compression`` and mirror back."""
        comp = self.compression
        if comp is not None:
            if not isinstance(comp, CompressionConfig):
                raise TypeError(
                    "FLConfig.compression must be a repro.core.wire."
                    f"CompressionConfig or None, got {type(comp)}")
            # disagreement (not mere presence) is the conflict, so the
            # mirrored flats survive dataclasses.replace round-trips
            mirror_qb = 0 if comp.is_auto else int(comp.bits)
            if self.quantize_bits not in (0, mirror_qb) or \
                    (self.error_feedback
                     and not comp.error_feedback):
                raise ValueError(
                    "FLConfig.quantize_bits/error_feedback conflict with "
                    "compression=CompressionConfig(...); pass one "
                    "spelling, not both")
        else:
            if self.error_feedback:
                assert self.quantize_bits > 0, \
                    "error feedback needs quantization"
            if self.quantize_bits:
                warnings.warn(
                    "FLConfig(quantize_bits=..., error_feedback=...) is "
                    "deprecated; pass compression=CompressionConfig("
                    "bits=..., error_feedback=...) instead",
                    DeprecationWarning, stacklevel=3)
                comp = CompressionConfig(
                    bits=int(self.quantize_bits),
                    error_feedback=self.error_feedback)
                object.__setattr__(self, "compression", comp)
        if comp is not None:
            # mirror: flat ints keep showing the effective width (0 for
            # the adaptive allocator, whose width is per-round)
            object.__setattr__(self, "quantize_bits",
                               0 if comp.is_auto else int(comp.bits))
            object.__setattr__(self, "error_feedback", comp.error_feedback)
        if comp is not None and not scls.supports_quantize:
            raise ValueError(
                f"strategy {self.algo!r} declares supports_quantize=False "
                "(fedadp aggregates pruned neurons, not quantized deltas)")

    def __post_init__(self):
        # resolve through the strategy registry: unknown algos raise a
        # ValueError listing every registered name, and per-strategy
        # capability flags replace engine special-cases.
        scls = get_strategy_cls(self.algo)
        assert self.mode in ("vmap", "scan")
        assert 1 <= self.top_n <= self.clients_per_round
        self._normalize_algo_options(scls)
        self._normalize_compression(scls)
        if self.mode == "scan":
            if not scls.supports_scan:
                raise ValueError(
                    f"strategy {self.algo!r} declares supports_scan=False")
            if self.compression is not None:
                raise NotImplementedError(_SCAN_COMPRESSION_MSG)
        if self.partition is not None and \
                not isinstance(self.partition, ParamPartition):
            raise TypeError(
                "FLConfig.partition must be a repro.core.partition."
                f"ParamPartition or None, got {type(self.partition)}")
        if self.mesh is not None:
            assert self.mode == "vmap", \
                "client-axis sharding needs stacked clients (mode='vmap')"
            if not scls.supports_mesh:
                raise ValueError(
                    f"strategy {self.algo!r} declares supports_mesh=False "
                    "(a declared capability — see "
                    "repro.federated.strategies)")
            d = client_mesh_size(self.mesh)
            assert self.clients_per_round % d == 0, \
                f"K={self.clients_per_round} must divide over {d} devices"
            if self.agg_group_size:
                gs = self.agg_group_size
                if not (1 <= gs <= d and d % gs == 0):
                    raise ValueError(
                        f"FLConfig.agg_group_size={gs} must be in [1, {d}] "
                        f"and divide the 'clients' axis size {d}")
            if self.shard_samples and self.num_clients % d:
                raise ValueError(
                    f"FLConfig.shard_samples needs N={self.num_clients} "
                    f"divisible by the {d} 'clients'-axis devices (the "
                    "static client→device affinity assigns N/D clients "
                    "per device)")
        else:
            if self.agg_group_size:
                raise ValueError(
                    "FLConfig.agg_group_size is a mesh-round knob; pass "
                    "mesh=make_client_mesh(...) too")
            if self.shard_samples:
                raise ValueError(
                    "FLConfig.shard_samples is a mesh-round knob; pass "
                    "mesh=make_client_mesh(...) too")
        if self.telemetry is not None and \
                not isinstance(self.telemetry, TelemetryConfig):
            raise TypeError(
                "FLConfig.telemetry must be a repro.telemetry."
                f"TelemetryConfig or None, got {type(self.telemetry)}")


# ======================================================================
# Cross-round strategy state: shared plumbing
# ======================================================================
# Strategy state is ``{"client": {name: (N, ...) store}, "global":
# {name: tree}}`` or None (see FLStrategy.init_state). The helpers below
# are the *only* state plumbing the engines/drivers need — there is no
# per-strategy special-casing here; the EF residual store is just the
# client entry named "residual" declared by the quantize wrapper.
_IS_SPEC = lambda x: isinstance(x, P)     # noqa: E731  (tree_map is_leaf)


def _state_round_view(state: Optional[dict], clients) -> Optional[dict]:
    """Round-local view of the state: client stores are replaced by the
    participants' gathered ``(K, ...)`` rows; global entries pass through."""
    if not state or not state.get("client"):
        return state
    return {**state, "client": {n_: _gather_rows(s, clients)
                                for n_, s in state["client"].items()}}


def _state_scatter(state: Optional[dict], new_state: dict,
                   clients) -> Optional[dict]:
    """Persist a round's updated state: client rows are scattered back into
    the ``(N, ...)`` stores, global entries are replaced wholesale."""
    if state is None:
        return None
    out = dict(new_state)
    if state.get("client"):
        out["client"] = {n_: _scatter_rows(state["client"][n_], clients, r)
                         for n_, r in new_state["client"].items()}
    return out


def _state_shard_specs(state: dict, sspecs: dict, ax: Optional[str]) -> dict:
    """shard_map in/out specs for the round-local state: client rows get a
    leading 'clients' axis over their entry's trailing-dim specs
    (``residual_store_specs``-style placement), global entries use their
    specs as-is (replicated by default)."""
    out = {}
    if "client" in state:
        out["client"] = {
            n_: jax.tree.map(lambda s: P(ax, *s), sspecs["client"][n_],
                             is_leaf=_IS_SPEC)
            for n_ in state["client"]}
    if "global" in state:
        out["global"] = {n_: sspecs["global"][n_] for n_ in state["global"]}
    return out


def _state_model_gather(state: dict, sspecs: dict) -> dict:
    """Inside shard_map on a 2-D mesh: reassemble full state leaves from
    'model'-axis shards (client rows carry a leading client axis the specs
    do not mention, hence offset=1). No-op for replicated entries."""
    out = dict(state)
    for kind, off in (("client", 1), ("global", 0)):
        if state.get(kind):
            out[kind] = {n_: tree_all_gather(e, sspecs[kind][n_],
                                             MODEL_AXIS, offset=off)
                         for n_, e in state[kind].items()}
    return out


def _state_model_slice(state: dict, sspecs: dict, m: int) -> dict:
    """Inverse of :func:`_state_model_gather` (exact data movement)."""
    out = dict(state)
    for kind, off in (("client", 1), ("global", 0)):
        if state.get(kind):
            out[kind] = {n_: tree_shard_slice(e, sspecs[kind][n_], m,
                                              MODEL_AXIS, offset=off)
                         for n_, e in state[kind].items()}
    return out


def _place_state(state: dict, params, strategy, mesh) -> dict:
    """Device-put a (possibly host/numpy) state onto the mesh: client
    stores replicated over the client-id axis with 'model'-axis-sharded
    trailing dims, global entries per their declared specs."""
    sspecs = strategy.state_specs(params, state, mesh)
    out = dict(state)
    if state.get("client"):
        out["client"] = {
            n_: jax.device_put(e, to_named(jax.tree.map(
                lambda s: P(None, *s), sspecs["client"][n_],
                is_leaf=_IS_SPEC), mesh))
            for n_, e in state["client"].items()}
    if state.get("global"):
        out["global"] = {
            n_: jax.device_put(e, to_named(sspecs["global"][n_], mesh))
            for n_, e in state["global"].items()}
    return out


# ======================================================================
# Round builders
# ======================================================================
def _build_round_vmap_sharded(local_update, umap: UnitMap, flcfg: FLConfig,
                              strategy):
    """Mesh-sharded round: ``shard_map`` over ('clients'[, 'model']) axes.

    Every device trains its K/D local clients (vmap over the local stack),
    then the round is stitched back together with collectives:

    - FedLDF divergence feedback: per-device (K/D, U) divergence blocks are
      ``all_gather``'d into the full (K, U) matrix so the top-n selection —
      which needs *all* clients' divergences (Eq. 4) — is computed
      replicated on every device; each device then slices back its own rows.
    - Aggregation (Eq. 5), the loss sum, and the (additive) comm-byte
      totals all travel in ONE fused ``psum``: local unnormalised
      numerators/denominator from
      :func:`repro.core.aggregation.stacked_psum_parts`, local
      :func:`repro.core.comm.round_comm` byte counts, one collective, then
      the replicated division epilogue (``stacked_psum_finalize``) — a
      single cross-device rendezvous per round instead of one per
      parameter leaf. (:func:`~repro.core.aggregation.aggregate_stacked`
      with ``axis_name`` / ``round_comm(axis_name=...)`` offer the same
      reductions as standalone calls.)
    - Strategy state (the cross-round seam): global entries enter and
      leave replicated — selection and ``update_state`` run on identical
      replicated inputs on every device, so the state trajectory matches
      the unsharded engines. Client entries (e.g. the EF residual store's
      rows) stay device-local (spec P('clients', ...) rows); the driver's
      store scatter handles the store update.

    On a 2-D ('clients', 'model') mesh the round is additionally
    FSDP-sharded: parameter leaves (and EF residual rows) enter and leave
    the body as 1/M 'model'-axis shards per :func:`fl_param_specs`. The
    full model is reassembled *transiently* for local training
    (``tree_all_gather``), and the Eq. 5 numerators are sliced back to this
    device's shard (``tree_shard_slice``) **before** the fused psum — which
    reduces over 'clients' only, so each model column reduces its own 1/M
    slice and the at-rest params/store replication cliff disappears along
    with 1/M of the collective payload. Gather/slice are exact data
    movement, so a 2-D trajectory matches the 1-D mesh bit-for-bit and the
    unsharded path to the usual fp32 psum-order tolerance.

    Outputs are replicated (per model column) by construction
    (psum/all_gather/replicated inputs); replication *checking* is
    disabled — see :func:`repro.launch.mesh.shard_map_norep` — and covered
    by the equivalence tests instead (tests/test_shard_engine.py,
    tests/test_model_axis.py).
    """
    mesh, ax = flcfg.mesh, CLIENT_AXIS
    d = client_mesh_size(mesh)
    m = model_mesh_size(mesh)
    k = flcfg.clients_per_round
    kloc = k // d
    tele = flcfg.telemetry
    taps_on = tele is not None and tele.taps
    # hierarchical two-tier reduce: group-local psum + group-leader ring.
    # gs == 0 (default) or gs == d keeps the single flat psum — reduce_
    # lowers to exactly the pre-tier collective, byte-identical rounds.
    gs = flcfg.agg_group_size
    hier = bool(gs) and gs < d

    def reduce_(vals):
        if hier:
            return agg.hierarchical_psum(vals, ax, axis_size=d,
                                         group_size=gs)
        return jax.lax.psum(vals, ax)

    def body(pspecs, sspecs, fspecs, params, batch, data_sizes, key, state,
             frozen):
        # everything in here sees the LOCAL shard: kloc clients per device,
        # and (2-D mesh) 1/M 'model'-axis blocks of each param/state leaf.
        # With a partition, ``params`` is the TRAINABLE sub-pytree — the
        # frozen base is gathered transiently for local training and never
        # touches the psum or the outputs.
        params_shard = params
        if m > 1:
            params = tree_all_gather(params, pspecs, MODEL_AXIS)
            if frozen is not None:
                frozen = tree_all_gather(frozen, fspecs, MODEL_AXIS)
            if state is not None:
                state = _state_model_gather(state, sspecs)
        if frozen is None:
            locals_, losses = jax.vmap(local_update, in_axes=(None, 0))(
                params, batch)
        else:
            locals_, losses = jax.vmap(
                lambda p, b: local_update(p, b, frozen),
                in_axes=(None, 0))(params, batch)

        divs = None
        if strategy.needs_divergence:
            divs_loc = jax.vmap(lambda p: umap.divergence(p, params))(locals_)
            divs = jax.lax.all_gather(divs_loc, ax, axis=0, tiled=True)
        # selection is replicated: divs are all-gathered and global state
        # entries enter replicated (client state rows are device-local and
        # must not drive selection under a mesh — see FLStrategy docs)
        selection = strategy.select_with_state(state, divs, key, k,
                                               umap.num_units,
                                               flcfg.top_n)    # (K, U), repl.
        sel_loc = local_rows(selection, ax, kloc)

        # ONE fused cross-device reduction per round: the Eq. 5 numerators/
        # denominator, the loss sum, and the (additive) comm-byte totals
        # all ride the same psum — a single rendezvous instead of one per
        # parameter leaf, which is what keeps the sharded round scaling on
        # oversubscribed CPU meshes as well as accelerator fabrics. The
        # psum reduces over 'clients' ONLY: on a 2-D mesh each model
        # column reduces its own 1/M numerator slice, leaving the 'model'
        # shards intact. Strategies plug in via psum_parts/psum_finalize
        # (the two halves of their aggregate()); comm_profile is called on
        # the LOCAL selection rows, so every field but savings_frac must
        # be additive over the client axis.
        wire = None
        if strategy.packed_upload:
            # packed wire-format uplink: quantize the local client deltas
            # into PackedPayload buffers and reduce them through the fused
            # dequant+EF+accumulate kernel — the parts stay additive over
            # the clients axis, so they ride the same fused psum below
            res_rows = (state["client"]["residual"]
                        if strategy.tracks_residuals else None)
            parts, denom_loc, new_rows, wire = strategy.uplink_psum_parts(
                locals_, params, umap, sel_loc, divs, data_sizes, res_rows)
            if strategy.tracks_residuals:
                state = {**state, "client": {**state["client"],
                                             "residual": new_rows}}
        else:
            if strategy.transforms_upload:
                res_rows = (state["client"]["residual"]
                            if strategy.tracks_residuals else None)
                uploads, cand_res = jax.vmap(
                    lambda loc, res: strategy.transform_upload(
                        loc, params, umap, res),
                    in_axes=(0, 0 if res_rows is not None else None),
                )(locals_, res_rows)
                if strategy.tracks_residuals:
                    new_rows = jax.vmap(
                        lambda cand, old, s: strategy.update_residual(
                            cand, old, s, umap, params),
                        in_axes=(0, 0, 0))(cand_res, res_rows, sel_loc)
                    state = {**state, "client": {**state["client"],
                                                 "residual": new_rows}}
            else:
                uploads = locals_
            parts, denom_loc = strategy.psum_parts(uploads, umap, sel_loc,
                                                   data_sizes,
                                                   global_params=params)
        if m > 1:
            parts = tree_shard_slice(parts, pspecs, m, MODEL_AXIS)
            # a param-structured denominator (element-wise aggregation,
            # e.g. FedADP's mask counts) shards with the numerators; the
            # Eq. 5 (U,) unit denominator stays replicated
            if jax.tree.structure(denom_loc) == jax.tree.structure(parts):
                denom_loc = tree_shard_slice(denom_loc, pspecs, m,
                                             MODEL_AXIS)
        if wire is not None:
            # charge the packed payload's actual wire bytes (bit-width
            # vector + headers), not fp32 unit sizes
            comm_loc = strategy.comm_profile(
                sel_loc, umap, unit_bytes_override=wire["unit_bytes"])
        else:
            comm_loc = strategy.comm_profile(sel_loc, umap)
        comm_add = {n_: v for n_, v in comm_loc.items()
                    if n_ != "savings_frac"}   # byte counts are additive
        # telemetry taps: the client-state squared-norm partials (EF
        # residual rows are device-local) ride the SAME fused psum — taps
        # must not add a second rendezvous. Disabled telemetry keeps the
        # original 3-tuple, so the compiled round is bit-identical.
        tap_client_sq = None
        if taps_on and state is not None and state.get("client"):
            tap_client_sq = taps_mod.client_sqsums(state["client"])
        if tap_client_sq is not None:
            (parts, denom), loss_sum, comm, tap_client_sq = reduce_(
                ((parts, denom_loc), losses.sum(), comm_add,
                 tap_client_sq))
        else:
            (parts, denom), loss_sum, comm = reduce_(
                ((parts, denom_loc), losses.sum(), comm_add))
        new_params = strategy.psum_finalize(parts, denom, umap,
                                            params_shard, params_shard)
        comm["savings_frac"] = 1.0 - comm["uplink_total"] / \
            comm["fedavg_uplink"]
        # per-tier aggregation-traffic split: static topology × payload
        # arithmetic added AFTER the reduce (deliberately not riding the
        # psum, so the flat path's collective payload — and trajectory —
        # stays byte-identical to the pre-tier engine). Payload = this
        # device's Eq. 5 numerator tree (1/M slice on a 2-D mesh).
        for n_, v in comm_mod.agg_tier_bytes(umap.total_bytes / m, d,
                                             gs if hier else 0).items():
            comm[n_] = jnp.float32(v)
        loss = loss_sum / k
        metrics = {"loss": loss, "comm": comm, "selection": selection}
        if state is not None:
            # replicated transition: selection/divs/global entries are
            # identical on every device, so the new global state is too;
            # client rows go back to this device's 1/M store-row shard
            state = strategy.update_state(state, selection, divs, umap,
                                          key=key)
        if taps_on:
            # replicated by construction: selection/divs/global state are
            # identical everywhere, client norms were just psum'd. The
            # non-None client_sq stops collect() from re-deriving norms
            # from the device-local rows.
            metrics["taps"] = taps_mod.collect(
                strategy, state, selection, divs, umap,
                client_sq=tap_client_sq if tap_client_sq is not None else {},
                extra=(None if wire is None else
                       {"wire_unit_bytes": wire["unit_bytes"],
                        "wire_bits": wire["bits"]}))
        if state is not None:
            if m > 1:
                state = _state_model_slice(state, sspecs, m)
            metrics["state"] = state
        return new_params, metrics

    out_metrics_spec = {"loss": P(), "comm": P(), "selection": P()}
    if taps_on:
        out_metrics_spec["taps"] = P()

    def round_fn(params, batch, data_sizes, key, state=None, frozen=None):
        # specs are pure shape logic, computed at trace time (the drivers
        # jit round_fn, so this runs once per compiled configuration).
        # State and frozen-base arguments are optional; both presences are
        # static per configuration, so the arg list is assembled once.
        pspecs = fl_param_specs(params, mesh)
        fspecs = None if frozen is None else fl_param_specs(frozen, mesh)
        sspecs = None
        in_specs = [pspecs, P(ax), P(ax), P()]
        args = [params, batch, data_sizes, key]
        out_metrics = dict(out_metrics_spec)
        if state is not None:
            sspecs = strategy.state_specs(params, state, mesh)
            st_specs = _state_shard_specs(state, sspecs, ax)
            in_specs.append(st_specs)
            args.append(state)
            out_metrics["state"] = st_specs
        if frozen is not None:
            # the frozen base enters model-sharded like the params and is
            # consumed inside the body (all-gathered transiently on a 2-D
            # mesh); it is never part of the outputs
            in_specs.append(fspecs)
            args.append(frozen)
        has_state, has_frozen = state is not None, frozen is not None

        def call(p, b, s, key_, *rest):
            rest = list(rest)
            st = rest.pop(0) if has_state else None
            fz = rest.pop(0) if has_frozen else None
            return body(pspecs, sspecs, fspecs, p, b, s, key_, st, fz)

        sharded = shard_map_norep(call, mesh, in_specs=tuple(in_specs),
                                  out_specs=(pspecs, out_metrics))
        return sharded(*args)

    return round_fn


def build_round_vmap(loss_fn, umap: UnitMap, flcfg: FLConfig,
                     opt: Optimizer | None = None):
    """Round function with parallel (stacked) clients.

    With ``flcfg.mesh`` set, the client axis is sharded over the mesh's
    'clients' axis (every device trains K/D clients; aggregation is a
    cross-device psum) — same signature, same semantics, fp32-tolerance
    identical trajectories.
    """
    opt = opt or sgd(flcfg.lr)
    local_update = make_local_update(loss_fn, opt, flcfg.local_steps,
                                     remat=flcfg.remat,
                                     partition=flcfg.partition)
    strategy = make_strategy(flcfg)
    if flcfg.mesh is not None:
        return _build_round_vmap_sharded(local_update, umap, flcfg, strategy)
    k = flcfg.clients_per_round
    taps_on = flcfg.telemetry is not None and flcfg.telemetry.taps

    def round_fn(params: Pytree, batch: dict, data_sizes: jnp.ndarray,
                 key: jax.Array, state: Optional[dict] = None,
                 frozen: Optional[Pytree] = None):
        if frozen is None:
            locals_, losses = jax.vmap(local_update, in_axes=(None, 0))(
                params, batch)
        else:
            # partitioned round: ``params`` is the trainable sub-pytree;
            # the frozen base broadcasts into every client's local step
            locals_, losses = jax.vmap(
                lambda p, b: local_update(p, b, frozen),
                in_axes=(None, 0))(params, batch)

        # divergence feedback (Eq. 3) is computed on the TRUE local model —
        # upload transforms (e.g. quantization) below only affect the
        # uploaded payload.
        divs = None
        if strategy.needs_divergence:
            divs = jax.vmap(lambda p: umap.divergence(p, params))(locals_)
        selection = strategy.select_with_state(state, divs, key, k,
                                               umap.num_units, flcfg.top_n)

        wire = None
        if strategy.packed_upload:
            # packed wire-format uplink: the strategy quantizes the client
            # deltas into PackedPayload buffers and reduces them through
            # the fused dequant+EF+accumulate kernel in one shot
            res_rows = (state["client"]["residual"]
                        if strategy.tracks_residuals else None)
            new_params, new_rows, wire = strategy.uplink_round(
                locals_, params, umap, selection, divs, data_sizes,
                res_rows)
            if strategy.tracks_residuals:
                state = {**state, "client": {**state["client"],
                                             "residual": new_rows}}
        else:
            if strategy.transforms_upload:
                # e.g. quantized deltas: the server reconstructs
                # Ĝ + dequant(Q(Δ + e)) for uploaded layers; error
                # feedback residuals update only where a layer was
                # actually uploaded (s[k,u] = 1). The residual rows ride
                # the state seam as the client entry named "residual"
                # (see FLStrategy.init_state).
                res_rows = (state["client"]["residual"]
                            if strategy.tracks_residuals else None)
                uploads, cand_res = jax.vmap(
                    lambda loc, res: strategy.transform_upload(
                        loc, params, umap, res),
                    in_axes=(0, 0 if res_rows is not None else None),
                )(locals_, res_rows)
                if strategy.tracks_residuals:
                    new_rows = jax.vmap(
                        lambda cand, old, s: strategy.update_residual(
                            cand, old, s, umap, params),
                        in_axes=(0, 0, 0))(cand_res, res_rows, selection)
                    state = {**state, "client": {**state["client"],
                                                 "residual": new_rows}}
            else:
                uploads = locals_
            new_params = strategy.aggregate(uploads, umap, selection,
                                            data_sizes, params)
        if wire is not None:
            comm = strategy.comm_profile(
                selection, umap, unit_bytes_override=wire["unit_bytes"])
        else:
            comm = strategy.comm_profile(selection, umap)
        metrics = {"loss": losses.mean(), "comm": comm,
                   "selection": selection}
        if state is not None:
            metrics["state"] = strategy.update_state(state, selection, divs,
                                                     umap, key=key)
        if taps_on:
            # client rows in the post-update_state view carry the
            # post-residual-update values (update_state preserves entries
            # it does not own), matching the mesh engine's tap timing.
            metrics["taps"] = taps_mod.collect(
                strategy, metrics.get("state"), selection, divs, umap,
                extra=(None if wire is None else
                       {"wire_unit_bytes": wire["unit_bytes"],
                        "wire_bits": wire["bits"]}))
        return new_params, metrics

    return round_fn


def build_round_scan(loss_fn, umap: UnitMap, flcfg: FLConfig,
                     opt: Optimizer | None = None):
    """Round function with sequential clients + two-phase recompute.

    Memory (``eq5_weighted`` strategies): O(global + 1 local +
    1 accumulator) models, independent of K — selected layers are streamed
    into the Eq. 5 accumulator as each client trains. Strategies whose
    aggregation is not an Eq. 5 weighted mean (e.g. FedADP's element-wise
    neuron masks) instead have their sequentially-trained locals *stacked*
    by the scan and fed to the same :meth:`FLStrategy.aggregate` hook used
    in vmap mode — O(K) parameter memory, but still O(1) activation
    memory, which is the scan engine's binding constraint for deep models.
    """
    if getattr(flcfg, "compression", None) is not None or \
            getattr(flcfg, "quantize_bits", 0):
        raise NotImplementedError(_SCAN_COMPRESSION_MSG)
    strategy = make_strategy(flcfg)
    if not strategy.supports_scan:
        raise NotImplementedError(
            f"strategy {strategy.name!r} declares supports_scan=False")
    opt = opt or sgd(flcfg.lr)
    local_update = make_local_update(loss_fn, opt, flcfg.local_steps,
                                     remat=flcfg.remat,
                                     partition=flcfg.partition)
    k = flcfg.clients_per_round
    taps_on = flcfg.telemetry is not None and flcfg.telemetry.taps

    def round_fn(params: Pytree, batch: dict, data_sizes: jnp.ndarray,
                 key: jax.Array, state: Optional[dict] = None,
                 frozen: Optional[Pytree] = None):
        lu = (local_update if frozen is None
              else lambda p, b: local_update(p, b, frozen))
        # ---- phase 1: divergence feedback (only if the policy needs it)
        if strategy.needs_divergence:
            def phase1(carry, batch_k):
                local, loss = lu(params, batch_k)
                return carry, (umap.divergence(local, params), loss)

            _, (divs, losses1) = jax.lax.scan(phase1, None, batch)
        else:
            divs, losses1 = None, None

        selection = strategy.select_with_state(state, divs, key, k,
                                               umap.num_units, flcfg.top_n)

        if strategy.eq5_weighted:
            w, denom = agg.unit_weights(selection, data_sizes)
            frac = w / jnp.where(denom > 0, denom, 1.0)[None, :]   # (K, U)

            # ---- phase 2: recompute local training, stream layers in
            def phase2(acc, inp):
                batch_k, frac_k = inp
                local, loss = lu(params, batch_k)
                return agg.streaming_add(acc, local, umap, frac_k), loss

            acc0 = agg.streaming_init(params)
            acc, losses2 = jax.lax.scan(phase2, acc0, (batch, frac))
            new_params = agg.streaming_finalize(acc, umap, denom, params)
        else:
            # ---- phase 2 (non-Eq.5 aggregation, e.g. FedADP): train
            # sequentially, let the scan stack the locals, and call the
            # same stacked-clients aggregate hook as the vmap engine.
            def phase2_stack(carry, batch_k):
                return carry, lu(params, batch_k)

            _, (stacked, losses2) = jax.lax.scan(phase2_stack, None, batch)
            new_params = strategy.aggregate(stacked, umap, selection,
                                            data_sizes, params)

        comm = strategy.comm_profile(selection, umap)
        loss = (losses1 if losses1 is not None else losses2).mean()
        metrics = {"loss": loss, "comm": comm, "selection": selection}
        if state is not None:
            metrics["state"] = strategy.update_state(state, selection, divs,
                                                     umap, key=key)
        if taps_on:
            metrics["taps"] = taps_mod.collect(
                strategy, metrics.get("state"), selection, divs, umap)
        return new_params, metrics

    return round_fn


def build_round_fn(loss_fn, umap: UnitMap, flcfg: FLConfig,
                   opt: Optimizer | None = None):
    if flcfg.mode == "vmap":
        return build_round_vmap(loss_fn, umap, flcfg, opt)
    return build_round_scan(loss_fn, umap, flcfg, opt)


# ----------------------------------------------------------------------
# Compiled-callable cache. Both drivers build their jitted functions from
# (loss_fn, umap, flcfg) alone; rebuilding a fresh ``jax.jit`` object per
# driver call would force a full retrace + XLA recompile every time
# ``run_training``/``run_training_scan`` is invoked (the jit cache is keyed
# on function identity). The cache keeps one compiled callable per distinct
# configuration, so repeated runs — benchmark repetitions, sweeps, tests —
# pay compilation once.
# ----------------------------------------------------------------------
_JIT_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_JIT_CACHE_MAX = 64   # LRU bound: evicts one cold entry, never the hot set


def _umap_cache_key(umap: UnitMap) -> tuple:
    return (umap.names, tuple(sorted(umap.spans.items())), umap.unit_bytes)


def _trace_flcfg(flcfg: FLConfig) -> FLConfig:
    """Cache-key view of the config: telemetry is reduced to its
    trace-relevant subset (taps on/off, full-selection on/off), so two runs
    differing only in host-side observability — ledger path, run id,
    verbosity, profiler window — share one compiled round instead of
    forcing a retrace."""
    if flcfg.telemetry is None:
        return flcfg
    return dataclasses.replace(flcfg,
                               telemetry=flcfg.telemetry.trace_key())


def _cached(kind: str, loss_fn, umap: UnitMap, flcfg: FLConfig, build):
    """NOTE: keyed on ``loss_fn`` *identity* — pass a stable function (module
    function, bound method, or a lambda created once) to hit the cache;
    a lambda re-created per call misses every time. The key also carries
    the *class* currently registered under ``flcfg.algo``: the registry is
    mutable (unregister + re-register is the iterate-on-a-plugin flow), so
    an equal FLConfig must not reuse a round compiled for a previously
    registered strategy class.

    Every lookup is reported to the telemetry retrace counters
    (:func:`repro.telemetry.profiling.note_engine_cache`): a nonzero
    ``<kind>_builds`` delta across identical driver calls is the retrace
    regression tests/test_telemetry.py pins."""
    key = (kind, loss_fn, _umap_cache_key(umap), _trace_flcfg(flcfg),
           get_strategy_cls(flcfg.algo))
    try:
        fn = _JIT_CACHE.get(key)
    except TypeError:       # unhashable loss_fn — skip caching
        prof_mod.note_engine_cache(kind, hit=False)
        return build()
    if fn is None:
        prof_mod.note_engine_cache(kind, hit=False)
        while len(_JIT_CACHE) >= _JIT_CACHE_MAX:
            _JIT_CACHE.popitem(last=False)
        fn = _JIT_CACHE[key] = build()
    else:
        prof_mod.note_engine_cache(kind, hit=True)
        _JIT_CACHE.move_to_end(key)
    return fn


# ======================================================================
# Multi-round drivers
# ======================================================================
def _run_meta(flcfg: FLConfig, *, driver: str, umap: UnitMap, seed: int,
              sampler: str, start_round: int, rounds: int,
              run_id: str, partition_info: Optional[dict] = None) -> dict:
    """Ledger run-header metadata: everything a consumer needs to label a
    segment without rebuilding the model (notably the layer-unit names,
    which index every per-layer tap vector — under a partition those are
    the *trainable* units, e.g. per-adapter-layer ``blocks/<d>`` labels,
    and ``partition`` carries the trainable/frozen param+byte totals)."""
    mesh = flcfg.mesh
    agg_meta = None
    if mesh is not None:
        d = client_mesh_size(mesh)
        gs = flcfg.agg_group_size if (
            flcfg.agg_group_size and flcfg.agg_group_size < d) else d
        agg_meta = {"group_size": int(gs), "num_groups": int(d // gs),
                    "tiers": 1 if gs == d else 2}
    return {"run_id": run_id, "driver": driver, "algo": flcfg.algo,
            "agg": agg_meta, "shard_samples": bool(flcfg.shard_samples),
            "partition": partition_info,
            "mode": flcfg.mode, "sampler": sampler, "seed": seed,
            "start_round": start_round, "rounds": rounds,
            "num_clients": flcfg.num_clients,
            "clients_per_round": flcfg.clients_per_round,
            "top_n": flcfg.top_n,
            "quantize_bits": flcfg.quantize_bits,
            "compression": (None if flcfg.compression is None else
                            {"bits": flcfg.compression.bits,
                             "error_feedback":
                                 flcfg.compression.error_feedback,
                             "fused": flcfg.compression.fused}),
            "mesh": (dict(mesh.shape) if mesh is not None else None),
            "units": list(umap.names),
            "unit_bytes": [float(b) for b in np.asarray(umap.unit_bytes)]}


@dataclasses.dataclass
class TrainLog:
    rounds: list = dataclasses.field(default_factory=list)
    losses: list = dataclasses.field(default_factory=list)
    test_errors: list = dataclasses.field(default_factory=list)
    uplink_mb: list = dataclasses.field(default_factory=list)
    meter: comm_mod.CommMeter = dataclasses.field(
        default_factory=comm_mod.CommMeter)
    # strategy state after the last round (None for stateless strategies);
    # feed it back as run_training*(server_state=...) with
    # start_round=<rounds done> to continue a run bit-identically
    # (checkpoint via repro.checkpoint.save_server_state)
    final_state: Optional[dict] = None


def _gather_rows(store: Pytree, clients: jnp.ndarray) -> Pytree:
    return jax.tree.map(lambda l: l[clients], store)


def _scatter_rows(store: Pytree, clients: jnp.ndarray,
                  rows: Pytree) -> Pytree:
    # explicit cast: EF update arithmetic runs fp32, the store keeps each
    # leaf's own dtype (an implicit fp32->bf16 scatter cast is a
    # FutureWarning on jax 0.4.x and an error on newer releases)
    return jax.tree.map(
        lambda full, r: full.at[clients].set(r.astype(full.dtype)),
        store, rows)


def run_training(params: Pytree, loss_fn, fldata, flcfg: FLConfig,
                 rounds: int, eval_fn: Optional[Callable[[Pytree], float]] = None,
                 eval_every: int = 10, seed: int = 0,
                 verbose: bool = False,
                 sampler: str = "host",
                 start_round: int = 0,
                 server_state: Optional[dict] = None
                 ) -> tuple[Pytree, TrainLog]:
    """Full FL training loop (paper Algorithm 1 ServerExecute), host-driven.

    One Python iteration per round — the reference oracle for
    :func:`run_training_scan`. ``sampler`` picks the RNG stream:

    - ``"host"`` (default): numpy client sampling + numpy batch gathering,
      byte-compatible with the original seed driver;
    - ``"jax"``: the engine's key schedule (:func:`round_keys` +
      :func:`sample_clients_jax` + :meth:`ClientShards.gather`), so a fixed
      seed yields the *same trajectory* as ``run_training_scan``.

    Strategy cross-round state (the EF residual store, FedLAMA's interval
    accumulators, any :meth:`FLStrategy.init_state` schema) is threaded
    through rounds generically: client-entry rows are gathered/scattered
    per round, the final state lands in ``log.final_state``. To resume a
    checkpointed run, pass ``start_round=<rounds already done>`` and
    ``server_state=<saved state>`` — with ``sampler="jax"`` the per-round
    keys are a pure function of (seed, absolute round index), so the
    continuation is bit-identical to the uninterrupted run (the "host"
    sampler's sequential numpy stream is not resumable).
    """
    assert sampler in ("host", "jax"), sampler
    partition, frozen, pinfo = flcfg.partition, None, None
    if partition is not None:
        # split ONCE: everything downstream — unit map, strategy state,
        # round functions, comm accounting — sees the trainable sub-pytree;
        # the frozen base rides along as an untouched round input
        pinfo = partition_counts(partition, params)
        params, frozen = partition.split(params)
    umap = UnitMap.build(params)
    strategy = make_strategy(flcfg)
    round_fn = _cached("round", loss_fn, umap, flcfg,
                       lambda: jax.jit(build_round_fn(loss_fn, umap, flcfg)))
    log = TrainLog()
    tele = flcfg.telemetry
    sink = ProgressSink.for_run(tele, verbose)
    sample_sys = tele is not None and tele.sample_system
    win = prof_mod.ProfileWindow.from_config(tele)
    ledger = None
    if tele is not None and tele.wants_ledger:
        ledger = RoundLedger(tele.ledger_path, meta=_run_meta(
            flcfg, driver="host", umap=umap, seed=seed, sampler=sampler,
            start_round=start_round, rounds=rounds, run_id=tele.run_id,
            partition_info=pinfo))
    if flcfg.mesh is not None:
        # place the global model over the mesh: replicated across 'clients'
        # so the sharded round starts from device-local copies everywhere,
        # and (2-D mesh) FSDP-sharded 1/M per device along the 'model' axis.
        # The frozen base gets the same policy: big base leaves land
        # model-sharded, small (indivisible) adapters replicate.
        params = jax.device_put(
            params, to_named(fl_param_specs(params, flcfg.mesh), flcfg.mesh))
        if frozen is not None:
            frozen = jax.device_put(
                frozen,
                to_named(fl_param_specs(frozen, flcfg.mesh), flcfg.mesh))
    merged = ((lambda p: p) if partition is None
              else (lambda p: partition.merge(p, frozen)))
    if server_state is not None:
        # checkpoint-loaded states arrive as numpy; the row scatter below
        # needs jax arrays (and a mesh needs explicit placement)
        state = (_place_state(server_state, params, strategy, flcfg.mesh)
                 if flcfg.mesh is not None
                 else jax.tree.map(jnp.asarray, server_state))
    else:
        state = strategy.init_state(params, flcfg.num_clients, flcfg.mesh)
    if sampler == "jax":
        shards = (fldata if isinstance(fldata, ClientShards)
                  else ClientShards.from_federated(fldata))
        if flcfg.mesh is not None:
            shards = shards.place(flcfg.mesh,
                                  shard_samples=flcfg.shard_samples)
        all_sizes_dev = shards.data_sizes()
        base_key = jax.random.PRNGKey(seed)
    elif flcfg.shard_samples:
        raise ValueError(
            "FLConfig.shard_samples needs sampler='jax' (the host sampler "
            "never builds device-resident ClientShards)")
    else:
        rng = np.random.default_rng(seed)
        all_sizes = fldata.data_sizes()
        # per-round algorithm keys: fold the round index into one base key.
        # (The old ``PRNGKey(seed * 100003 + t)`` schedule degenerated to
        # ``key = t`` at seed=0 and let nearby seeds replay each other's
        # round keys once t crossed the stride.)
        host_base = jax.random.PRNGKey(seed)

    try:
        for t in range(start_round, start_round + rounds):
            win.round_begin(t)
            wall0 = time.perf_counter() if sample_sys else None
            if sampler == "jax":
                ck, bk, key = round_keys(base_key, t)
                # affinity-laid-out shards (num_groups > 1) switch the
                # cohort draw to per-group sampling, matching the scan
                # engine's trajectory on the same shards
                clients = sample_clients_grouped(ck, flcfg.num_clients,
                                                 flcfg.clients_per_round,
                                                 shards.num_groups)
                batch = shards.gather(clients, flcfg.batch_per_client, bk)
                sizes = all_sizes_dev[clients]
            else:
                clients = sample_clients(rng, flcfg.num_clients,
                                         flcfg.clients_per_round)
                batch = fldata.round_batch(clients, flcfg.batch_per_client,
                                           rng)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                sizes = jnp.asarray(all_sizes[clients])
                key = jax.random.fold_in(host_base, t)
                clients = jnp.asarray(clients)
            kw = {} if frozen is None else {"frozen": frozen}
            if state is not None:
                st_rows = _state_round_view(state, clients)
                params, metrics = round_fn(params, batch, sizes, key,
                                           st_rows, **kw)
                state = _state_scatter(state, metrics["state"], clients)
            else:
                params, metrics = round_fn(params, batch, sizes, key, **kw)
            log.meter.update(metrics["comm"])
            log.rounds.append(t)
            loss_t = float(metrics["loss"])     # device sync
            log.losses.append(loss_t)
            log.uplink_mb.append(log.meter.uplink_bytes / 1e6)
            if ledger is not None:
                # the float() pull above synced the round, so wall_s is
                # real compute time, not dispatch time
                wall_s = (time.perf_counter() - wall0
                          if wall0 is not None else None)
                mem = (prof_mod.device_memory_peak() if sample_sys
                       else None)
                ledger.round(
                    t, loss_t, jax.device_get(metrics["comm"]),
                    log.meter.uplink_bytes,
                    taps=(jax.device_get(metrics["taps"])
                          if "taps" in metrics else None),
                    selection=(metrics["selection"]
                               if tele.full_selection else None),
                    wall_s=wall_s, mem_peak_bytes=mem)
            if eval_fn is not None and (t % eval_every == 0
                                        or t == start_round + rounds - 1):
                err = float(eval_fn(merged(params)))
                log.test_errors.append((t, err, log.meter.uplink_bytes))
                if ledger is not None:
                    ledger.eval(t, err, log.meter.uplink_bytes)
                sink.round(t, loss_t, test_error=err,
                           uplink_bytes=log.meter.uplink_bytes)
            elif sink.enabled and t % 10 == 0:
                sink.round(t, loss_t)
            win.round_end(t)
    finally:
        win.close()
        if ledger is not None:
            ledger.close()
    log.final_state = state
    return merged(params), log


# ======================================================================
# Device-resident multi-round engine
# ======================================================================
def _eval_cuts(rounds: int, eval_every: int, do_eval: bool) -> list[int]:
    """Block boundaries: cut after round t iff the host driver would eval
    there (t % eval_every == 0 or t == rounds-1); one block when not
    evaluating."""
    if not do_eval:
        return [rounds]
    return sorted({t + 1 for t in range(rounds)
                   if t % eval_every == 0 or t == rounds - 1})


def _build_block_fn(loss_fn, umap: UnitMap, flcfg: FLConfig):
    """Compiled multi-round block: ``lax.scan`` of the round function.

    ``run_block(carry, shards, all_sizes, base_key, t0, num)`` advances the
    carry (params, strategy state, comm accumulator) by ``num`` rounds
    starting at round index ``t0``, entirely on device. ``t0`` is a traced
    scalar so eval blocks of equal length share one executable. A
    stateless strategy carries ``None`` — zero extra carry leaves.
    """
    round_fn = build_round_fn(loss_fn, umap, flcfg)
    strategy = make_strategy(flcfg)
    mesh = flcfg.mesh
    # sharded engine: pin the gathered round batch (and client-state rows)
    # to the 'clients' axis so XLA partitions the gather itself — each
    # device materialises only its own K/D clients' samples, never the
    # full batch. Client-state rows additionally keep their leaves'
    # 'model'-axis sharding, and the scattered store is pinned back to its
    # (replicated-N, 'model') layout so the scan carry's sharding stays
    # fixed across rounds.
    client_spec = (NamedSharding(mesh, P(CLIENT_AXIS))
                   if mesh is not None else None)

    def constrain_state(st, params, *, rows: bool):
        """Pin a round-local state view (rows=True) or the full store
        (rows=False) to its mesh layout; no-op off-mesh / stateless."""
        if mesh is None or st is None or not st.get("client"):
            return st
        sspecs = strategy.state_specs(params, st, mesh)
        lead = CLIENT_AXIS if rows else None
        out = dict(st)
        out["client"] = {
            n_: jax.lax.with_sharding_constraint(
                e, jax.tree.map(
                    lambda s: NamedSharding(mesh, P(lead, *s)),
                    sspecs["client"][n_], is_leaf=_IS_SPEC))
            for n_, e in st["client"].items()}
        return out

    def one_round(carry, t, shards, all_sizes, base_key, frozen):
        params, state, acc = carry
        ck, bk, ak = round_keys(base_key, t)
        # shards.num_groups is static pytree aux: affinity-laid-out shards
        # flip the cohort draw to per-group sampling at trace time (a
        # num_groups of 1 lowers to exactly sample_clients_jax).
        def sample(k_):
            return sample_clients_grouped(k_, flcfg.num_clients,
                                          flcfg.clients_per_round,
                                          shards.num_groups)

        if mesh is not None:
            # run the RNG draws replicated inside shard_map: the
            # non-partitionable threefry lowering changes values when XLA
            # shards it (see ClientShards.gather / replicated_rng) — the
            # participant draw gets the same treatment as the batch draw.
            clients = replicated_rng(sample, mesh)(ck)
        else:
            clients = sample(ck)
        batch = shards.gather(clients, flcfg.batch_per_client, bk, mesh=mesh)
        sizes = all_sizes[clients]
        if client_spec is not None:
            batch = jax.lax.with_sharding_constraint(batch, client_spec)
            sizes = jax.lax.with_sharding_constraint(sizes, client_spec)
        kw = {} if frozen is None else {"frozen": frozen}
        if state is not None:
            st_rows = constrain_state(_state_round_view(state, clients),
                                      params, rows=True)
            params, metrics = round_fn(params, batch, sizes, ak, st_rows,
                                       **kw)
            state = constrain_state(
                _state_scatter(state, metrics.pop("state"), clients),
                params, rows=False)
        else:
            params, metrics = round_fn(params, batch, sizes, ak, **kw)
        acc = comm_mod.comm_acc_update(acc, metrics["comm"])
        per_round = {"loss": metrics["loss"],
                     "uplink_bytes": acc["uplink_bytes"]}
        # telemetry widens the stacked per-round OUTPUTS (scan ys), never
        # the carry — disabled telemetry leaves zero extra carry leaves
        # and the per_round dict exactly as above (bit-identical blocks).
        tele = flcfg.telemetry
        if tele is not None:
            per_round["comm"] = metrics["comm"]
            if tele.taps:
                per_round["taps"] = metrics["taps"]
            if tele.full_selection:
                per_round["selection"] = metrics["selection"]
        return (params, state, acc), per_round

    # carry buffers are donated so XLA reuses them across eval blocks; on
    # CPU donation is a no-op warning, so only request it where it works.
    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()

    @functools.partial(jax.jit, static_argnames=("num",),
                       donate_argnums=donate)
    def run_block(carry, shards, all_sizes, base_key, t0, num, frozen=None):
        # ``frozen`` is a real (pytree) argument, not a closure: closed-over
        # arrays would be baked into the jaxpr as constants and re-staged
        # per driver call. It is never donated — it outlives every block.
        body = functools.partial(one_round, shards=shards,
                                 all_sizes=all_sizes, base_key=base_key,
                                 frozen=frozen)
        return jax.lax.scan(body, carry, t0 + jnp.arange(num))

    return run_block


def run_training_scan(params: Pytree, loss_fn, fldata, flcfg: FLConfig,
                      rounds: int,
                      eval_fn: Optional[Callable[[Pytree], float]] = None,
                      eval_every: int = 10, seed: int = 0,
                      verbose: bool = False,
                      start_round: int = 0,
                      server_state: Optional[dict] = None
                      ) -> tuple[Pytree, TrainLog]:
    """Device-resident FL training: ``jax.lax.scan`` over rounds.

    The whole schedule — client sampling (``jax.random.choice``), round-batch
    gathering from device-resident shards, local training, selection,
    aggregation, communication accounting, and strategy cross-round state
    updates (EF residuals, FedLAMA intervals, …) — runs inside one jitted
    scan per eval block, with the carry (params, strategy state, comm
    accumulator) donated between blocks. Host↔device traffic is one
    stacked (losses, uplink) pull per block instead of several scalar
    syncs per round.

    ``fldata`` may be a :class:`~repro.data.FederatedData` (uploaded once)
    or a prebuilt :class:`~repro.data.ClientShards`. Same seed ⇒ same
    trajectory as ``run_training(sampler="jax")`` (fp32 tolerance).

    Resume: the per-round keys are ``fold_in(PRNGKey(seed), t)`` with
    ``t`` the *absolute* round index, so
    ``start_round=<rounds done>, server_state=<log.final_state or a loaded
    checkpoint>`` continues a run bit-identically to one that never
    stopped (regression-tested in tests/test_state_seam.py).
    """
    partition, frozen, pinfo = flcfg.partition, None, None
    if partition is not None:
        pinfo = partition_counts(partition, params)
        params, frozen = partition.split(params)
    umap = UnitMap.build(params)
    shards = (fldata if isinstance(fldata, ClientShards)
              else ClientShards.from_federated(fldata))
    strategy = make_strategy(flcfg)
    run_block = _cached("block", loss_fn, umap, flcfg,
                        lambda: _build_block_fn(loss_fn, umap, flcfg))
    if flcfg.mesh is not None:
        # replicated over 'clients', FSDP-sharded over 'model' (2-D mesh);
        # the frozen base follows the same placement policy
        params = jax.device_put(
            params, to_named(fl_param_specs(params, flcfg.mesh), flcfg.mesh))
        if frozen is not None:
            frozen = jax.device_put(
                frozen,
                to_named(fl_param_specs(frozen, flcfg.mesh), flcfg.mesh))
        shards = shards.place(flcfg.mesh,
                              shard_samples=flcfg.shard_samples)
    merged = ((lambda p: p) if partition is None
              else (lambda p: partition.merge(p, frozen)))
    if jax.default_backend() in ("tpu", "gpu"):
        # run_block donates its carry; copy once so the caller's param
        # buffers survive the first block (state/acc are fresh).
        params = jax.tree.map(jnp.copy, params)
    if server_state is not None:
        state0 = (_place_state(server_state, params, strategy, flcfg.mesh)
                  if flcfg.mesh is not None else server_state)
    else:
        state0 = strategy.init_state(params, flcfg.num_clients, flcfg.mesh)
    carry = (params, state0, comm_mod.comm_acc_init())
    all_sizes = shards.data_sizes()
    base_key = jax.random.PRNGKey(seed)
    log = TrainLog()
    tele = flcfg.telemetry
    sink = ProgressSink.for_run(tele, verbose)
    sample_sys = tele is not None and tele.sample_system
    win = prof_mod.ProfileWindow.from_config(tele)
    ledger = None
    if tele is not None and tele.wants_ledger:
        ledger = RoundLedger(tele.ledger_path, meta=_run_meta(
            flcfg, driver="scan", umap=umap, seed=seed, sampler="jax",
            start_round=start_round, rounds=rounds, run_id=tele.run_id,
            partition_info=pinfo))
    run_kw = {} if frozen is None else {"frozen": frozen}
    t0 = 0
    try:
        for cut in _eval_cuts(rounds, eval_every, eval_fn is not None):
            num = cut - t0
            win.block_begin(start_round + t0, start_round + cut)
            wall0 = time.perf_counter() if sample_sys else None
            carry, per_round = run_block(carry, shards, all_sizes, base_key,
                                         jnp.int32(start_round + t0), num,
                                         **run_kw)
            losses = np.asarray(per_round["loss"])
            uplink = np.asarray(per_round["uplink_bytes"])
            # the np.asarray pulls above synced the block, so block wall
            # time is real compute; per-round wall is the amortised share
            block_wall = (time.perf_counter() - wall0
                          if wall0 is not None else None)
            log.rounds.extend(range(start_round + t0, start_round + cut))
            log.losses.extend(float(l) for l in losses)
            log.uplink_mb.extend(float(u) / 1e6 for u in uplink)
            if ledger is not None:
                wall_each = (block_wall / num
                             if block_wall is not None else None)
                mem = (prof_mod.device_memory_peak() if sample_sys
                       else None)
                comm_stack = jax.device_get(per_round["comm"])
                taps_stack = (jax.device_get(per_round["taps"])
                              if "taps" in per_round else None)
                sel_stack = (np.asarray(per_round["selection"])
                             if "selection" in per_round else None)
                for i in range(num):
                    ledger.round(
                        start_round + t0 + i, losses[i],
                        jax.tree.map(lambda a, i=i: a[i], comm_stack),
                        uplink[i],
                        taps=(jax.tree.map(lambda a, i=i: a[i], taps_stack)
                              if taps_stack is not None else None),
                        selection=(sel_stack[i] if sel_stack is not None
                                   else None),
                        wall_s=wall_each, mem_peak_bytes=mem)
            t_last = start_round + cut - 1
            if eval_fn is not None:
                err = float(eval_fn(merged(carry[0])))
                log.test_errors.append((t_last, err, float(uplink[-1])))
                if ledger is not None:
                    ledger.eval(t_last, err, float(uplink[-1]))
                sink.round(t_last, float(losses[-1]), test_error=err,
                           uplink_bytes=float(uplink[-1]))
            elif sink.enabled:
                sink.round(t_last, float(losses[-1]))
            win.block_end(start_round + cut)
            t0 = cut
    finally:
        win.close()
        if ledger is not None:
            ledger.close()
    params, final_state, acc = carry
    log.meter = comm_mod.CommMeter.from_accumulator(acc)
    log.final_state = final_state
    return merged(params), log
