"""ServerExecute (paper Algorithm 1) — round function builders + driver.

Two execution modes produce identical aggregation semantics (tested):

- ``vmap``: all K clients train in parallel (client axis shardable over the
  'data' mesh axis) and their models are materialised stacked — the paper's
  own regime (small models, many clients).
- ``scan``: clients run sequentially over the whole mesh; FedLDF divergence
  feedback needs all K divergence vectors *before* deciding what to
  aggregate, so the round runs two passes of deterministic local training
  (phase 1: divergence only; phase 2: accumulate selected layers). This is
  protocol-level rematerialization — O(1)-client memory for LLM-scale FL.

Algorithms: fedldf (paper), fedavg (Eq. 1), random (per-layer random-n),
hdfl (client dropout [7]), fedadp (neuron pruning [6], vmap mode only).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core import comm as comm_mod
from repro.core import fedadp as fedadp_mod
from repro.core import selection as sel
from repro.core.units import UnitMap
from repro.federated.client import make_local_update
from repro.federated.sampling import sample_clients
from repro.optim import sgd
from repro.optim.opt import Optimizer

Pytree = Any

ALGOS = ("fedldf", "fedavg", "random", "hdfl", "fedadp")


@dataclasses.dataclass(frozen=True)
class FLConfig:
    algo: str = "fedldf"
    num_clients: int = 50          # N
    clients_per_round: int = 20    # K
    top_n: int = 4                 # n (per-layer uploads)
    local_steps: int = 1
    lr: float = 0.05
    mode: str = "vmap"             # vmap | scan
    fedadp_keep: float = 0.2       # FedADP keep fraction (equal-comm setting)
    batch_per_client: int = 32
    # beyond-paper: quantized delta upload (0 = off) + error feedback
    quantize_bits: int = 0
    error_feedback: bool = False

    def __post_init__(self):
        assert self.algo in ALGOS, self.algo
        assert self.mode in ("vmap", "scan")
        assert 1 <= self.top_n <= self.clients_per_round
        if self.error_feedback:
            assert self.quantize_bits > 0, "error feedback needs quantization"


def _select(algo: str, divs: Optional[jnp.ndarray], key, k: int, u: int,
            n: int) -> jnp.ndarray:
    if algo == "fedldf":
        return sel.topn_divergence(divs, n)
    if algo == "fedavg":
        return sel.full_participation(k, u)
    if algo == "random":
        return sel.random_per_layer(key, k, u, n)
    if algo == "hdfl":
        return sel.client_dropout(key, k, u, n)
    raise ValueError(algo)


# ======================================================================
# Round builders
# ======================================================================
def build_round_vmap(loss_fn, umap: UnitMap, flcfg: FLConfig,
                     opt: Optimizer | None = None):
    """Round function with parallel (stacked) clients."""
    opt = opt or sgd(flcfg.lr)
    local_update = make_local_update(loss_fn, opt, flcfg.local_steps)
    k = flcfg.clients_per_round

    def round_fn(params: Pytree, batch: dict, data_sizes: jnp.ndarray,
                 key: jax.Array, residuals: Pytree = None):
        locals_, losses = jax.vmap(local_update, in_axes=(None, 0))(
            params, batch)

        if flcfg.algo == "fedadp":
            new_params = fedadp_mod.aggregate_fedadp(
                locals_, params, data_sizes, flcfg.fedadp_keep)
            selection = sel.full_participation(k, umap.num_units)
            comm = comm_mod.round_comm(selection, umap,
                                       divergence_feedback=False)
            # overwrite with FedADP's own accounting
            comm["uplink_total"] = jnp.float32(0.0) + comm["fedavg_uplink"] \
                * flcfg.fedadp_keep
            comm["savings_frac"] = 1.0 - flcfg.fedadp_keep
            return new_params, {"loss": losses.mean(), "comm": comm,
                                "selection": selection}

        # divergence feedback (Eq. 3) is computed on the TRUE local model —
        # quantization below only affects the uploaded payload.
        divs = None
        if flcfg.algo == "fedldf":
            divs = jax.vmap(lambda p: umap.divergence(p, params))(locals_)
        selection = _select(flcfg.algo, divs, key, k, umap.num_units,
                            flcfg.top_n)

        metrics_extra = {}
        if flcfg.quantize_bits:
            # beyond-paper: the server reconstructs Ĝ + dequant(Q(Δ + e))
            # for uploaded layers; error feedback residuals update only
            # where a layer was actually uploaded (s[k,u] = 1).
            from repro.core.compress import compress_upload
            theta_hat, cand_res = jax.vmap(
                lambda loc, res: compress_upload(
                    loc, params, umap, flcfg.quantize_bits, res),
                in_axes=(0, 0 if residuals is not None else None),
            )(locals_, residuals)
            locals_agg = theta_hat
            if flcfg.error_feedback:
                def keep_where_selected(kidx_res, kidx_old, sel_row):
                    gate = umap.expand_to_leaves(kidx_res, sel_row)
                    old = kidx_old if kidx_old is not None else \
                        agg.streaming_init(params)
                    return jax.tree.map(
                        lambda g_, n_, o_: g_ * n_ + (1 - g_) * o_,
                        gate, kidx_res, old)

                new_residuals = jax.vmap(
                    keep_where_selected,
                    in_axes=(0, 0 if residuals is not None else None, 0),
                )(cand_res, residuals, selection)
                metrics_extra["residuals"] = new_residuals
        else:
            locals_agg = locals_

        new_params = agg.aggregate_stacked(locals_agg, umap, selection,
                                           data_sizes, fallback=params)
        comm = comm_mod.round_comm(
            selection, umap,
            divergence_feedback=(flcfg.algo == "fedldf"),
            param_bytes_override=(flcfg.quantize_bits / 8.0
                                  if flcfg.quantize_bits else None))
        return new_params, {"loss": losses.mean(), "comm": comm,
                            "selection": selection, **metrics_extra}

    return round_fn


def build_round_scan(loss_fn, umap: UnitMap, flcfg: FLConfig,
                     opt: Optimizer | None = None):
    """Round function with sequential clients + two-phase recompute.

    Memory: O(global + 1 local + 1 accumulator) models, independent of K.
    """
    if flcfg.algo == "fedadp":
        raise NotImplementedError("fedadp needs stacked clients (vmap mode)")
    opt = opt or sgd(flcfg.lr)
    local_update = make_local_update(loss_fn, opt, flcfg.local_steps)
    k = flcfg.clients_per_round
    needs_divergence = flcfg.algo == "fedldf"

    def round_fn(params: Pytree, batch: dict, data_sizes: jnp.ndarray,
                 key: jax.Array):
        # ---- phase 1: divergence feedback (only if the policy needs it)
        if needs_divergence:
            def phase1(carry, batch_k):
                local, loss = local_update(params, batch_k)
                return carry, (umap.divergence(local, params), loss)

            _, (divs, losses1) = jax.lax.scan(phase1, None, batch)
        else:
            divs, losses1 = None, None

        selection = _select(flcfg.algo, divs, key, k, umap.num_units,
                            flcfg.top_n)
        w, denom = agg.unit_weights(selection, data_sizes)
        frac = w / jnp.where(denom > 0, denom, 1.0)[None, :]   # (K, U)

        # ---- phase 2: recompute local training, stream selected layers in
        def phase2(acc, inp):
            batch_k, frac_k = inp
            local, loss = local_update(params, batch_k)
            return agg.streaming_add(acc, local, umap, frac_k), loss

        acc0 = agg.streaming_init(params)
        acc, losses2 = jax.lax.scan(phase2, acc0, (batch, frac))
        new_params = agg.streaming_finalize(acc, umap, denom, params)

        comm = comm_mod.round_comm(selection, umap,
                                   divergence_feedback=needs_divergence)
        loss = (losses1 if losses1 is not None else losses2).mean()
        return new_params, {"loss": loss, "comm": comm,
                            "selection": selection}

    return round_fn


def build_round_fn(loss_fn, umap: UnitMap, flcfg: FLConfig,
                   opt: Optimizer | None = None):
    if flcfg.mode == "vmap":
        return build_round_vmap(loss_fn, umap, flcfg, opt)
    return build_round_scan(loss_fn, umap, flcfg, opt)


# ======================================================================
# Host-side training driver
# ======================================================================
@dataclasses.dataclass
class TrainLog:
    rounds: list = dataclasses.field(default_factory=list)
    losses: list = dataclasses.field(default_factory=list)
    test_errors: list = dataclasses.field(default_factory=list)
    uplink_mb: list = dataclasses.field(default_factory=list)
    meter: comm_mod.CommMeter = dataclasses.field(
        default_factory=comm_mod.CommMeter)


def run_training(params: Pytree, loss_fn, fldata, flcfg: FLConfig,
                 rounds: int, eval_fn: Optional[Callable[[Pytree], float]] = None,
                 eval_every: int = 10, seed: int = 0,
                 verbose: bool = False) -> tuple[Pytree, TrainLog]:
    """Full FL training loop (paper Algorithm 1 ServerExecute)."""
    umap = UnitMap.build(params)
    round_fn = jax.jit(build_round_fn(loss_fn, umap, flcfg))
    rng = np.random.default_rng(seed)
    log = TrainLog()
    all_sizes = fldata.data_sizes()

    for t in range(rounds):
        clients = sample_clients(rng, flcfg.num_clients,
                                 flcfg.clients_per_round)
        batch = fldata.round_batch(clients, flcfg.batch_per_client, rng)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        sizes = jnp.asarray(all_sizes[clients])
        key = jax.random.PRNGKey(seed * 100003 + t)
        params, metrics = round_fn(params, batch, sizes, key)
        log.meter.update(metrics["comm"])
        log.rounds.append(t)
        log.losses.append(float(metrics["loss"]))
        log.uplink_mb.append(log.meter.uplink_bytes / 1e6)
        if eval_fn is not None and (t % eval_every == 0 or t == rounds - 1):
            err = float(eval_fn(params))
            log.test_errors.append((t, err, log.meter.uplink_bytes))
            if verbose:
                print(f"round {t:4d} loss {metrics['loss']:.4f} "
                      f"test_err {err:.4f} uplink {log.meter.uplink_bytes/1e6:.1f}MB")
        elif verbose and t % 10 == 0:
            print(f"round {t:4d} loss {metrics['loss']:.4f}")
    return params, log
