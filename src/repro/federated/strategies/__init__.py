"""Strategy plugins for the federated round engine.

One engine, pluggable algorithms: the round builders in
:mod:`repro.federated.server` drive the jit-safe hook surface of
:class:`FLStrategy`, and ``FLConfig.algo`` resolves through the registry
here. See :mod:`repro.federated.strategies.base` for the hook contract and
capability flags, and the README's "Writing a strategy" section for a
walkthrough.

    from repro.federated.strategies import FLStrategy, register_strategy

    @register_strategy("mystrat")
    class MyStrategy(FLStrategy):
        def select(self, divs, key, k, u, n):
            ...

    FLConfig(algo="mystrat")   # now valid; appears in ALGOS + benches
"""
from repro.federated.strategies.base import (FLStrategy, get_strategy_cls,
                                             register_strategy,
                                             registered_algos,
                                             strategy_registry,
                                             unregister_strategy)
from repro.federated.strategies import builtin  # noqa: F401  (registers)
from repro.federated.strategies import fedlama  # noqa: F401  (registers)
from repro.federated.strategies.builtin import FedADPOptions, FedLPOptions
from repro.federated.strategies.compression import QuantizedUpload
from repro.federated.strategies.fedlama import FedLAMAOptions

__all__ = ["FLStrategy", "FedADPOptions", "FedLAMAOptions", "FedLPOptions",
           "QuantizedUpload", "get_strategy_cls", "make_strategy",
           "register_strategy", "registered_algos", "strategy_registry",
           "unregister_strategy"]


def make_strategy(flcfg) -> FLStrategy:
    """Resolve ``flcfg.algo`` and compose the quantize(+EF) wrapper when
    ``flcfg.compression`` (or the deprecated ``flcfg.quantize_bits``) is
    set. The engines call this once per round builder; the result is
    stateless and jit-closure-safe."""
    strat = get_strategy_cls(flcfg.algo)(flcfg)
    comp = getattr(flcfg, "compression", None)
    if comp is not None:
        strat = QuantizedUpload(strat, flcfg, comp)
    elif getattr(flcfg, "quantize_bits", 0):
        # duck-typed legacy cfg (FLConfig itself normalizes the flat
        # knobs into .compression in __post_init__)
        strat = QuantizedUpload(strat, flcfg)
    return strat
