"""FedLAMA: layer-wise adaptive aggregation intervals (arXiv:2110.10302).

Lee et al. observe that the layers of a federated model drift from the
global model at very different rates, and that most of the communication
budget is spent re-synchronising layers that have barely moved. FedLAMA
therefore aggregates each layer on its *own* interval: layers whose
accumulated discrepancy-per-byte is low are synchronised every
``λ·τ'`` rounds instead of every ``τ'`` rounds
(``FedLAMAOptions(tau=τ', lam=λ)`` via ``FLConfig(algo_options=...)``).

This is the first genuinely *stateful* strategy in the registry — it is
the proof workload of the cross-round state seam
(:meth:`FLStrategy.init_state` / :meth:`select_with_state` /
:meth:`update_state`). The state is three replicated ``(U,)`` vectors:

- ``ttl``       — rounds until each unit's next synchronisation (a unit is
  aggregated exactly when its ttl reaches 0; initialised to 0 so round 0
  is a full synchronisation that bootstraps the discrepancy estimate);
- ``interval``  — each unit's current aggregation interval
  τ_u ∈ {τ', λτ'};
- ``disc``      — the discrepancy estimate d_u refreshed at each unit's
  sync rounds from the engine's Eq. 3 divergence matrix
  (``d_u = mean_k ||θ_u^k − θ_u||``, exactly the per-layer model
  discrepancy of the paper's §III).

Interval assignment (the paper's Alg. 2 cutoff, in our unit vocabulary):
sort units by discrepancy-per-byte ``δ_u = d_u / z_u`` ascending and find
the cutoff ``j*`` where the cumulative discrepancy fraction ``ℓ_j``
balances the *remaining* cumulative size fraction ``1 − s_j`` — units
below the cutoff carry a lot of bytes but little drift, so they are
demoted to the long interval λτ'; units above keep the base interval τ'.
Everything is jit-safe (sort/cumsum/argmin on static ``(U,)`` shapes), so
the same selection trajectory falls out of the vmap, scan, and
mesh-sharded engines.

Simulation semantics: our engine models cross-device FL (clients are
re-initialised from the global model each round), so a layer that is not
synchronised this round simply keeps its previous global value (the
Eq. 5 zero-denominator fallback) and that round's local update to it is
discarded — uplink drops to ~``z·Σ_u 1/τ_u`` of FedAvg while the
high-drift layers still synchronise every τ' rounds.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.units import UnitMap
from repro.federated.strategies.base import FLStrategy, register_strategy


@dataclasses.dataclass(frozen=True)
class FedLAMAOptions:
    """FedLAMA knobs: base aggregation interval ``tau`` (τ') and the
    long-interval multiplier ``lam`` (λ)."""
    tau: int = 2
    lam: int = 2

    def __post_init__(self):
        if self.tau < 1 or self.lam < 1:
            raise ValueError(
                f"fedlama intervals must be >= 1, got tau={self.tau}"
                f" lam={self.lam}")


@register_strategy("fedlama")
class FedLAMA(FLStrategy):
    """Layer-wise adaptive aggregation intervals, driven by per-layer
    discrepancy accumulated across rounds in strategy state."""

    options_cls = FedLAMAOptions
    needs_divergence = True   # d_u comes from the engine's Eq. 3 matrix

    # ------------------------------------------------------------------
    def init_state(self, params, num_clients, mesh=None):
        u = UnitMap.build(params).num_units
        tau = float(self.opts.tau)
        return {"global": {
            "ttl": jnp.zeros((u,), jnp.float32),        # round 0: full sync
            "interval": jnp.full((u,), tau, jnp.float32),
            "disc": jnp.zeros((u,), jnp.float32),
        }}

    # ------------------------------------------------------------------
    def select(self, divs, key, k, u, n):
        raise NotImplementedError(
            "fedlama selection is interval state-driven; the engines call "
            "select_with_state (see the cross-round state seam in "
            "repro.federated.strategies.base)")

    def select_with_state(self, state, divs, key, k, u, n):
        # a unit is uploaded (by every participating client) exactly when
        # its interval expires — the selection matrix is the sync mask
        # broadcast over clients.
        sync = (state["global"]["ttl"] <= 0.0).astype(jnp.float32)   # (U,)
        return jnp.broadcast_to(sync[None, :], (k, u))

    # ------------------------------------------------------------------
    def _intervals(self, disc: jnp.ndarray, umap: UnitMap) -> jnp.ndarray:
        """Alg.-2 cutoff: τ_u = λτ' for low-discrepancy-per-byte units,
        τ' for the rest. Falls back to τ' everywhere while no discrepancy
        has been observed yet (round 0)."""
        tau = jnp.float32(self.opts.tau)
        lam = jnp.float32(self.opts.lam)
        z = umap.unit_bytes_array()                       # (U,) bytes
        delta = disc / z                                  # drift per byte
        order = jnp.argsort(delta)                        # ascending
        d_sorted = disc[order]
        z_sorted = z[order]
        total_d = jnp.sum(d_sorted)
        ell = jnp.cumsum(d_sorted) / jnp.where(total_d > 0, total_d, 1.0)
        s = jnp.cumsum(z_sorted) / jnp.sum(z_sorted)
        jstar = jnp.argmin(jnp.abs(ell - (1.0 - s)))      # balance point
        long_sorted = (jnp.arange(disc.shape[0]) <= jstar)
        tau_sorted = jnp.where(long_sorted, lam * tau, tau)
        inv = jnp.argsort(order)                          # unsort
        adaptive = tau_sorted[inv]
        return jnp.where(total_d > 0, adaptive,
                         jnp.full_like(adaptive, tau)).astype(jnp.float32)

    def update_state(self, state, selection, divs, umap, key=None):
        g = state["global"]
        sync = g["ttl"] <= 0.0                            # (U,) bool
        d_now = divs.mean(axis=0)                         # (U,)
        disc = jnp.where(sync, d_now, g["disc"])
        interval = self._intervals(disc, umap)
        ttl = jnp.where(sync, interval - 1.0, g["ttl"] - 1.0)
        return {**state, "global": {"ttl": ttl, "interval": interval,
                                    "disc": disc}}


def expected_round_bytes(umap: UnitMap, k: int, tau: int,
                         lam: int = 2) -> dict:
    """Modeled steady-state per-round uplink for the comm table.

    Without a discrepancy trace the split between τ' and λτ' units is
    unknown, so this brackets the average round: ``hi`` assumes every unit
    stays on the base interval (worst case, payload = FedAvg/τ'), ``lo``
    assumes every unit is demoted to λτ'. Both include the per-round
    divergence-feedback vector (K·U float32 scalars) that drives the
    interval adaptation.
    """
    feedback = float(k * umap.num_units * 4)
    full = float(k * umap.total_bytes)
    return {"hi": full / tau + feedback,
            "lo": full / (lam * tau) + feedback}
