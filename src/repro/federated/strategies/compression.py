"""Quantize(+error-feedback) upload wrapper, as a composable strategy.

``FLConfig(quantize_bits=b)`` composes :class:`QuantizedUpload` around the
configured base strategy (see :func:`repro.federated.strategies.make_strategy`):
selection and aggregation delegate to the inner strategy unchanged, while
the per-client payload is re-expressed as ``Ĝ + dequant(Q_b(Δ + e))`` with
optional client-side error feedback (``FLConfig(error_feedback=True)``)
whose residuals advance only where a layer actually shipped. The comm
profile re-prices parameter bytes at ``b/8`` via the inner strategy's own
profile, so e.g. FedLP's keep-mask header survives composition.
"""
from __future__ import annotations

import jax

from repro.core import aggregation as agg
from repro.core.compress import compress_upload
from repro.federated.strategies.base import FLStrategy


class QuantizedUpload(FLStrategy):
    """Wrap ``inner`` with int-b delta quantization (+ error feedback)."""

    transforms_upload = True
    supports_scan = False       # quantized uploads need stacked clients
    supports_quantize = False   # no double-wrapping

    def __init__(self, inner: FLStrategy, cfg):
        super().__init__(cfg)
        assert cfg.quantize_bits > 0
        assert type(inner).supports_quantize, inner.name
        self.inner = inner
        self.name = f"{inner.name}+q{cfg.quantize_bits}"
        # mirror the inner strategy's declared behaviour (instance attrs
        # shadow the class-level flags)
        self.needs_divergence = inner.needs_divergence
        self.supports_mesh = inner.supports_mesh
        self.eq5_weighted = inner.eq5_weighted
        self.tracks_residuals = bool(cfg.error_feedback)

    # ---- cross-round state: inner state + the EF residual store ----
    def init_state(self, params, num_clients, mesh=None):
        # the error-feedback residual store is *declared* here as the
        # client state entry "residual" — the engines thread it like any
        # other strategy state (no special-cased plumbing in server.py)
        state = self.inner.init_state(params, num_clients, mesh)
        if self.tracks_residuals:
            from repro.launch.sharding import init_residual_store
            state = dict(state or {})
            client = dict(state.get("client") or {})
            client["residual"] = init_residual_store(params, num_clients,
                                                     mesh)
            state["client"] = client
        return state

    def select_with_state(self, state, divs, key, k, u, n):
        return self.inner.select_with_state(state, divs, key, k, u, n)

    def update_state(self, state, selection, divs, umap, key=None):
        # the engine already advanced the "residual" rows via
        # update_residual; the inner strategy's transition must preserve
        # entries it does not own (the default identity does)
        return self.inner.update_state(state, selection, divs, umap,
                                       key=key)

    # ---- delegated hooks ----
    def select(self, divs, key, k, u, n):
        return self.inner.select(divs, key, k, u, n)

    def telemetry_taps(self, state, selection, divs, umap):
        # a custom inner tap hook survives composition; the engines tap
        # the wrapper's EF residual norms via the client-state seam.
        return self.inner.telemetry_taps(state, selection, divs, umap)

    def aggregate(self, uploads, umap, selection, data_sizes,
                  global_params, axis_name=None):
        return self.inner.aggregate(uploads, umap, selection, data_sizes,
                                    global_params, axis_name=axis_name)

    def psum_parts(self, uploads, umap, sel_loc, data_sizes,
                   global_params=None):
        return self.inner.psum_parts(uploads, umap, sel_loc, data_sizes,
                                     global_params=global_params)

    def psum_finalize(self, parts, denom, umap, params_shard, fallback):
        return self.inner.psum_finalize(parts, denom, umap, params_shard,
                                        fallback)

    # ---- the wrapper's own behaviour ----
    def transform_upload(self, local, global_params, umap, residual):
        # Θ̂ = Ĝ + dequant(Q_b(Δ + e)); divergence feedback (Eq. 3) was
        # already computed on the TRUE local model by the engine, so only
        # the uploaded payload is affected.
        return compress_upload(local, global_params, umap,
                               self.cfg.quantize_bits, residual)

    def update_residual(self, cand_res, old_res, sel_row, umap,
                        global_params):
        # residuals advance only where a layer was actually uploaded
        # (s[k,u] = 1); elsewhere the old residual is carried forward.
        gate = umap.expand_to_leaves(cand_res, sel_row)
        old = old_res if old_res is not None else \
            agg.streaming_init(global_params)
        return jax.tree.map(lambda g_, n_, o_: g_ * n_ + (1 - g_) * o_,
                            gate, cand_res, old)

    def comm_profile(self, selection, umap, param_bytes_override=None):
        return self.inner.comm_profile(
            selection, umap,
            param_bytes_override=self.cfg.quantize_bits / 8.0)
