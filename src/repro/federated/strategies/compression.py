"""Quantize(+error-feedback) upload wrapper, as a composable strategy.

``FLConfig(compression=CompressionConfig(...))`` composes
:class:`QuantizedUpload` around the configured base strategy (see
:func:`repro.federated.strategies.make_strategy`): selection and
aggregation delegate to the inner strategy unchanged, while the per-client
payload is re-expressed as ``Ĝ + dequant(Q_b(Δ + e))`` with optional
client-side error feedback whose residuals advance only where a layer
actually shipped.

Two execution paths, chosen by ``CompressionConfig.fused``:

- **packed** (default): the stacked client deltas are quantized into a
  :class:`repro.core.wire.PackedPayload` — int8/int4 level buffers +
  per-unit scales + a per-unit bit-width vector (constant, or waterfilled
  from the round's Eq. 3 divergence stats when ``bits="auto"``) — and the
  whole dequant → EF-residual-update → masked weighted-accumulate chain
  runs in one pass per tile through the fused uplink kernel
  (``kernels/uplink``), never materialising per-client fp32
  reconstructions. Comm accounting prices the payload's actual wire bytes
  (``PackedPayload.unit_wire_bytes``) via ``unit_bytes_override``.
- **legacy** (``fused=False``): the pre-wire-format chain —
  ``transform_upload`` rebuilds fp32 ``Θ̂`` per client, ``update_residual``
  gates the EF rows, the inner strategy aggregates — kept as the unfused
  A/B reference (``benchmarks/kernel_bench.py``) and the equivalence
  target for the packed path's trajectory tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregation as agg
from repro.core import wire as wire_mod
from repro.core.compress import compress_upload
from repro.core.units import tree_sub
from repro.core.wire import CompressionConfig
from repro.federated.strategies.base import FLStrategy
from repro.kernels import ops as kops


class QuantizedUpload(FLStrategy):
    """Wrap ``inner`` with int-b delta quantization (+ error feedback)."""

    supports_scan = False       # quantized uploads need stacked clients
    supports_quantize = False   # no double-wrapping

    def __init__(self, inner: FLStrategy, cfg,
                 comp: CompressionConfig | None = None):
        super().__init__(cfg)
        if comp is None:
            comp = getattr(cfg, "compression", None)
        if comp is None:
            # duck-typed legacy cfg: only the flat knobs exist
            bits = int(getattr(cfg, "quantize_bits", 0))
            assert bits > 0
            comp = CompressionConfig(
                bits=bits,
                error_feedback=bool(getattr(cfg, "error_feedback", False)))
        assert type(inner).supports_quantize, inner.name
        self.comp = comp
        self.inner = inner
        self.name = f"{inner.name}+q{comp.bits}"
        # mirror the inner strategy's declared behaviour (instance attrs
        # shadow the class-level flags)
        self.needs_divergence = inner.needs_divergence or comp.is_auto
        self.supports_mesh = inner.supports_mesh
        self.eq5_weighted = inner.eq5_weighted
        self.tracks_residuals = comp.error_feedback
        self.packed_upload = comp.fused
        self.transforms_upload = not comp.fused

    # ---- cross-round state: inner state + the EF residual store ----
    def init_state(self, params, num_clients, mesh=None):
        # the error-feedback residual store is *declared* here as the
        # client state entry "residual" — the engines thread it like any
        # other strategy state (no special-cased plumbing in server.py)
        state = self.inner.init_state(params, num_clients, mesh)
        if self.tracks_residuals:
            from repro.launch.sharding import init_residual_store
            state = dict(state or {})
            client = dict(state.get("client") or {})
            client["residual"] = init_residual_store(params, num_clients,
                                                     mesh)
            state["client"] = client
        return state

    def select_with_state(self, state, divs, key, k, u, n):
        return self.inner.select_with_state(state, divs, key, k, u, n)

    def update_state(self, state, selection, divs, umap, key=None):
        # the engine already advanced the "residual" rows (via the packed
        # uplink or update_residual); the inner strategy's transition must
        # preserve entries it does not own (the default identity does)
        return self.inner.update_state(state, selection, divs, umap,
                                       key=key)

    # ---- delegated hooks ----
    def select(self, divs, key, k, u, n):
        return self.inner.select(divs, key, k, u, n)

    def telemetry_taps(self, state, selection, divs, umap):
        # a custom inner tap hook survives composition; the engines tap
        # the wrapper's EF residual norms via the client-state seam and
        # the packed wire bytes via the round's wire accounting.
        return self.inner.telemetry_taps(state, selection, divs, umap)

    def aggregate(self, uploads, umap, selection, data_sizes,
                  global_params, axis_name=None):
        return self.inner.aggregate(uploads, umap, selection, data_sizes,
                                    global_params, axis_name=axis_name)

    def psum_parts(self, uploads, umap, sel_loc, data_sizes,
                   global_params=None):
        return self.inner.psum_parts(uploads, umap, sel_loc, data_sizes,
                                     global_params=global_params)

    def psum_finalize(self, parts, denom, umap, params_shard, fallback):
        return self.inner.psum_finalize(parts, denom, umap, params_shard,
                                        fallback)

    # ==================================================================
    # Packed wire-format path (CompressionConfig.fused)
    # ==================================================================
    def _packed_reduce(self, locals_, global_params, umap, sel_rows, divs,
                       data_sizes, res_rows):
        """Stacked locals → packed payload → fused kernel reduction.

        Returns ``(num_parts, denom, new_res_rows, wire)`` where
        ``num_parts`` is the param-structured additive Eq. 5 numerator
        ``Σ_k w[k,u]·Θ̂_k = denom_u·Ĝ + Σ_k w·scale·levels`` (the second
        term via the fused uplink kernel), ``denom`` the ``(U,)`` local
        weight sums, and ``wire`` the payload's byte accounting. Additive
        over mesh client shards, so the mesh engine psums the parts
        exactly like the legacy ``psum_parts`` output.
        """
        comp = self.comp
        k = sel_rows.shape[0]
        bits = comp.bits_vector(umap, divs)                  # (U,) f32
        w, denom = agg.unit_weights(sel_rows, data_sizes)    # (K,U), (U,)
        ef = res_rows is not None

        def quantize_one(loc, res):
            delta = tree_sub(loc, global_params)
            if res is not None:
                # Δ+e in the leaf dtype first (bit-compat with the legacy
                # chain's bf16 rounding), then fp32 for the kernel
                v = jax.tree.map(
                    lambda d, e: (d + e.astype(d.dtype)).astype(jnp.float32),
                    delta, res)
            else:
                v = jax.tree.map(lambda d: d.astype(jnp.float32), delta)
            levels, scales = wire_mod.quantize_units(v, umap, bits)
            return jax.tree.map(lambda l: l.astype(jnp.int8), levels), \
                scales, v

        if ef:
            levels_k, scales_k, v_k = jax.vmap(quantize_one)(locals_,
                                                             res_rows)
        else:
            levels_k, scales_k, v_k = jax.vmap(
                lambda loc: quantize_one(loc, None))(locals_)

        # materialise the wire format (nibble-packs when every width ≤ 4);
        # nbytes/unit_wire_bytes below are computed from THIS payload
        payload = wire_mod.PackedPayload(
            wire_mod.pack_levels(levels_k, comp.storage_bits),
            scales_k, bits, storage_bits=comp.storage_bits)
        levels_k = wire_mod.unpack_levels(payload, v_k)

        num_parts = {}
        res_parts = {} if ef else None
        for key, (off, n) in umap.spans.items():
            w_seg = jax.lax.dynamic_slice(w, (0, off), (k, n))
            s_seg = jax.lax.dynamic_slice(scales_k, (0, off), (k, n))
            g_seg = jax.lax.dynamic_slice(sel_rows, (0, off), (k, n))
            d_seg = jax.lax.dynamic_slice(denom, (off,), (n,))

            def reduce_leaf(lv, vv, ee, g_leaf):
                # lv/vv/ee: (K, n, ...) stacked or (K, ...); flatten the
                # trailing dims so each unit is one kernel row
                lv2 = lv.reshape(k, n, -1)
                v2 = vv.reshape(k, n, -1)
                g2 = g_leaf.astype(jnp.float32).reshape(n, -1)
                if ee is not None:
                    e2 = ee.reshape(k, n, -1)
                    num2, res2 = kops.fused_uplink_ef(lv2, s_seg, w_seg,
                                                      g_seg, v2, e2)
                else:
                    num2 = kops.fused_uplink(lv2, s_seg, w_seg)
                    res2 = None
                # Σ_k w·Θ̂ = denom·Ĝ + Σ_k w·recon (the kernel term)
                num2 = num2 + d_seg[:, None] * g2
                num = num2.reshape(g_leaf.shape).astype(jnp.float32)
                res = (None if res2 is None
                       else res2.reshape((k,) + g_leaf.shape))
                return num, res

            glob = global_params[key]
            if ef:
                out = jax.tree.map(reduce_leaf, levels_k[key], v_k[key],
                                   res_rows[key], glob)
            else:
                out = jax.tree.map(
                    lambda lv, vv, g_leaf: reduce_leaf(lv, vv, None,
                                                       g_leaf),
                    levels_k[key], v_k[key], glob)
            num_parts[key] = jax.tree.map(lambda o: o[0], out,
                                          is_leaf=lambda o: isinstance(
                                              o, tuple))
            if ef:
                res_parts[key] = jax.tree.map(lambda o: o[1], out,
                                              is_leaf=lambda o: isinstance(
                                                  o, tuple))

        wire = {"unit_bytes": payload.unit_wire_bytes(umap),
                "bits": bits, "nbytes": payload.nbytes}
        return num_parts, denom, res_parts, wire

    def uplink_round(self, locals_, global_params, umap, selection, divs,
                     data_sizes, res_rows):
        parts, denom, new_rows, wire = self._packed_reduce(
            locals_, global_params, umap, selection, divs, data_sizes,
            res_rows)
        new_params = self.psum_finalize(parts, denom, umap, global_params,
                                        global_params)
        return new_params, new_rows, wire

    def uplink_psum_parts(self, locals_, global_params, umap, sel_loc,
                          divs, data_sizes, res_rows):
        return self._packed_reduce(locals_, global_params, umap, sel_loc,
                                   divs, data_sizes, res_rows)

    # ==================================================================
    # Legacy unfused chain (CompressionConfig.fused=False)
    # ==================================================================
    def transform_upload(self, local, global_params, umap, residual):
        # Θ̂ = Ĝ + dequant(Q_b(Δ + e)); divergence feedback (Eq. 3) was
        # already computed on the TRUE local model by the engine, so only
        # the uploaded payload is affected.
        return compress_upload(local, global_params, umap,
                               int(self.comp.bits), residual)

    def update_residual(self, cand_res, old_res, sel_row, umap,
                        global_params):
        # residuals advance only where a layer was actually uploaded
        # (s[k,u] = 1); elsewhere the old residual is carried forward.
        gate = umap.expand_to_leaves(cand_res, sel_row)
        old = old_res if old_res is not None else \
            agg.streaming_init(global_params)
        return jax.tree.map(lambda g_, n_, o_: g_ * n_ + (1 - g_) * o_,
                            gate, cand_res, old)

    # ==================================================================
    def comm_profile(self, selection, umap, param_bytes_override=None,
                     unit_bytes_override=None):
        if unit_bytes_override is None:
            if not self.comp.fused:
                # legacy pricing: uniform b/8 bytes per parameter
                return self.inner.comm_profile(
                    selection, umap,
                    param_bytes_override=int(self.comp.bits) / 8.0)
            # packed pricing at the configured widths; "auto" prices at
            # the avg_bits budget when no per-round vector is available
            # (the engines pass the round's actual allocation through
            # unit_bytes_override)
            b = (float(self.comp.avg_bits) if self.comp.is_auto
                 else float(int(self.comp.bits)))
            p = jnp.asarray(umap.unit_params, jnp.float32)
            unit_bytes_override = (jnp.ceil(p * b / 8.0)
                                   + wire_mod.UNIT_HEADER_BYTES)
        return self.inner.comm_profile(
            selection, umap, unit_bytes_override=unit_bytes_override)
