"""FLStrategy protocol + registry: pluggable FL algorithms for one engine.

An :class:`FLStrategy` packages everything algorithm-specific about a
federated round behind a fixed set of **jit-safe hooks**, so the three
execution shells in :mod:`repro.federated.server` (single-device ``vmap``,
sequential ``scan``, and the mesh-sharded ``shard_map`` round) share one
round body instead of re-implementing per-algorithm branches three times.

Hook contract (every hook is traced under ``jax.jit`` — no Python control
flow on traced values, no host callbacks, static shapes only):

- ``select(divs, key, k, u, n) -> (K, U) float32 selection matrix`` —
  which (client, layer-unit) pairs are uploaded and aggregated (Eq. 4 /
  the baselines' policies). ``divs`` is the (K, U) divergence matrix when
  :attr:`needs_divergence` is set, else ``None``. ``key`` is this round's
  algorithm PRNG key (same stream in every engine, so vmap/scan/sharded
  trajectories agree).
- ``transform_upload(local, global_params, umap, residual)
  -> (upload, candidate_residual)`` — per-client payload transform
  (identity by default; the quantize+error-feedback wrapper compresses
  here). Called under ``jax.vmap`` over the client axis; only consulted
  when :attr:`transforms_upload` is set.
- ``update_residual(cand_res, old_res, sel_row, umap, global_params)`` —
  per-client error-feedback residual update, gated on the selection row
  (residuals advance only where a layer actually shipped). Only consulted
  when :attr:`tracks_residuals` is set.
- ``aggregate(uploads, umap, selection, data_sizes, global_params,
  axis_name=None) -> new global params`` — the server-side reduction over
  client-stacked uploads. The default is the paper's Eq. 5 masked
  weighted mean (:func:`repro.core.aggregation.aggregate_stacked`).
- ``psum_parts`` / ``psum_finalize`` — the two halves of the aggregation
  that the mesh-sharded engine fuses into its single per-round ``psum``
  (additive local partials, then a replicated epilogue). The defaults
  implement Eq. 5; a strategy that overrides :meth:`aggregate` must either
  declare ``supports_mesh = False`` or override these to match.
- ``comm_profile(selection, umap, param_bytes_override=None,
  unit_bytes_override=None) -> dict`` — per-round communication
  accounting. Must preserve the ledger invariant
  ``uplink_payload + uplink_feedback == uplink_total`` (tested for every
  registered strategy). Inside the sharded round it is called on the
  *local* selection rows and every field except ``savings_frac`` must be
  additive across devices (the engine psums them and recomputes
  ``savings_frac``). ``unit_bytes_override`` carries the packed wire
  format's per-unit byte vector (``PackedPayload.unit_wire_bytes``) and
  takes precedence over the legacy uniform repricing.
- ``uplink_round`` / ``uplink_psum_parts`` — the packed-uplink fast path,
  consulted only when :attr:`packed_upload` is set: the strategy turns
  the stacked client locals directly into a packed wire payload
  (``core/wire``) and reduces it through the fused dequant+EF+Eq. 5
  kernel (``kernels/uplink``), never materialising per-client fp32
  reconstructions. ``uplink_round`` returns the finished global model
  (single-device round); ``uplink_psum_parts`` returns additive partials
  for the mesh engine's fused psum, finalized by ``psum_finalize``.

**Cross-round state seam** (optional; all three engines thread it):

- ``init_state(params, num_clients, mesh=None) -> state | None`` — declare
  cross-round state once before round 0. ``None`` (the default) keeps the
  strategy stateless and adds **zero** carry leaves to the engines. A
  stateful strategy returns ``{"client": {name: store}, "global":
  {name: tree}}``: each *client* entry is a per-client store whose leaves
  carry a leading ``(num_clients,)`` axis (rows for the round's
  participants are gathered before the round and scattered back after —
  exactly the error-feedback residual treatment, which is itself declared
  through this hook by the quantize wrapper); each *global* entry is a
  replicated pytree updated wholesale every round.
- ``select_with_state(state, divs, key, k, u, n)`` — state-aware selection;
  the engines always call this, and the default delegates to ``select``
  (so existing strategies are untouched). ``state`` is the *round-local*
  view: client entries hold the participants' ``(K, ...)`` rows.
- ``update_state(state, selection, divs, umap, key=None) -> state`` — the
  per-round state transition, called once per round after aggregation with
  the same replicated ``selection``/``divs`` every engine computed.
  Default: identity. Must be jit-safe and shape-preserving (the scan
  engine carries state through ``lax.scan``; changing a leaf's
  shape/dtype across rounds will fail to trace).
- ``state_specs(params, state, mesh) -> specs`` — mesh placement for state
  entries on a 2-D ('clients', 'model') mesh, mirroring
  ``residual_store_specs``: a same-structure dict of PartitionSpec trees
  for each entry's *trailing* dims (no client axis — the engine prepends
  the 'clients' axis for client rows itself). The default shards any
  param-shaped client entry like the parameters (``fl_param_specs``) and
  replicates everything else, which is right for residual/control-variate
  stores and for small global vectors alike.

In the mesh engine, global entries are replicated and may drive selection;
client entries enter hooks as the device-local rows (like EF residual
rows), so ``select_with_state``/``update_state`` must touch client entries
only element-wise per-row when ``supports_mesh`` is declared.

Capability flags (class attributes, read by ``FLConfig`` validation and
the engines):

- ``needs_divergence`` — the engine computes the (K, U) Eq. 3 divergence
  matrix (and accounts its feedback uplink) before calling ``select``.
- ``supports_scan`` — the strategy can run under ``mode="scan"``.
  Strategies with ``eq5_weighted`` stream clients through an O(1)-client
  accumulator; others have their sequentially-trained locals stacked by
  the scan and fed to the same :meth:`aggregate` hook (O(K) param memory,
  still O(1) activation memory).
- ``supports_mesh`` — the strategy can run client-sharded over a device
  mesh (requires Eq. 5 ``psum_parts``/``psum_finalize`` or overrides).
- ``supports_quantize`` — the quantize(+EF) wrapper may be composed on
  top (``FLConfig(compression=CompressionConfig(...))``).
- ``eq5_weighted`` — aggregation is exactly Eq. 5 over the selection
  matrix, so the engines may execute it as a streaming accumulation
  (scan) or a fused-psum partial reduction (mesh). Set it to ``False``
  whenever :meth:`aggregate` is overridden with different math.

Register with :func:`register_strategy`; ``FLConfig(algo=<name>)`` then
resolves through the registry, and the name shows up in
``repro.federated.ALGOS`` and ``benchmarks/fl_comparison.py``
automatically.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg
from repro.core import comm as comm_mod
from repro.core.units import UnitMap

Pytree = Any


class FLStrategy:
    """Base strategy: Eq. 5 aggregation over a subclass-chosen selection."""

    # registry name; filled in by @register_strategy
    name: str = "?"
    # per-strategy options dataclass accepted via FLConfig(algo_options=...)
    # (None = the strategy has no knobs beyond the shared FLConfig fields)
    options_cls: Optional[type] = None
    # ---- capability flags (see module docstring) ----
    needs_divergence: bool = False
    supports_scan: bool = True
    supports_mesh: bool = True
    supports_quantize: bool = True
    eq5_weighted: bool = True
    # ---- engine dispatch flags ----
    transforms_upload: bool = False
    tracks_residuals: bool = False
    # packed wire-format uplink: the engines route the whole
    # locals→payload→aggregate reduction through uplink_round /
    # uplink_psum_parts instead of transform_upload + aggregate
    packed_upload: bool = False

    def __init__(self, cfg):
        self.cfg = cfg   # the FLConfig (duck-typed; strategies read knobs)
        self.opts = self.resolve_options(cfg)

    @classmethod
    def resolve_options(cls, cfg):
        """The strategy's options instance for ``cfg``.

        ``FLConfig`` normalizes ``algo_options`` in ``__post_init__`` (flat
        deprecated knobs are folded in there), so this usually just reads
        ``cfg.algo_options``. Duck-typed cfgs without the field fall back
        to the options defaults. Returns ``None`` when the strategy
        declares no :attr:`options_cls`.
        """
        if cls.options_cls is None:
            return None
        opts = getattr(cfg, "algo_options", None)
        if opts is None:
            return cls.options_cls()
        if not isinstance(opts, cls.options_cls):
            raise TypeError(
                f"algo_options for strategy {cls.name!r} must be "
                f"{cls.options_cls.__name__}, got {type(opts).__name__}")
        return opts

    # ---- cross-round state seam (see module docstring) ----
    def init_state(self, params: Pytree, num_clients: int,
                   mesh=None) -> Optional[dict]:
        """Declare cross-round state; ``None`` (default) = stateless, and
        the engines add no carry leaves at all."""
        return None

    def state_specs(self, params: Pytree, state: dict, mesh) -> dict:
        """Mesh placement of state entries: a same-structure dict of
        PartitionSpec trees for each entry's *trailing* dims. Default:
        param-shaped client entries inherit the parameters' 'model'-axis
        sharding (``fl_param_specs`` — the residual-store treatment),
        everything else is replicated."""
        from repro.launch.sharding import fl_param_specs
        pspecs = fl_param_specs(params, mesh)
        pdef = jax.tree.structure(params)
        pshapes = [l.shape for l in jax.tree.leaves(params)]

        def entry_specs(entry, client: bool):
            if client and jax.tree.structure(entry) == pdef and \
                    [l.shape[1:] for l in jax.tree.leaves(entry)] == pshapes:
                return pspecs
            return jax.tree.map(lambda _: P(), entry)

        return {kind: {name: entry_specs(e, kind == "client")
                       for name, e in (state.get(kind) or {}).items()}
                for kind in ("client", "global")}

    def select_with_state(self, state: Optional[dict],
                          divs: Optional[jnp.ndarray], key, k: int, u: int,
                          n: int) -> jnp.ndarray:
        """State-aware selection — the engines' actual entry point. The
        default ignores ``state`` and delegates to :meth:`select`."""
        return self.select(divs, key, k, u, n)

    def update_state(self, state: dict, selection: jnp.ndarray,
                     divs: Optional[jnp.ndarray], umap: UnitMap,
                     key=None) -> dict:
        """Per-round state transition (identity by default). Runs once per
        round, after aggregation, with replicated inputs; must be jit-safe
        and preserve every leaf's shape/dtype."""
        return state

    # ------------------------------------------------------------------
    def select(self, divs: Optional[jnp.ndarray], key, k: int, u: int,
               n: int) -> jnp.ndarray:
        raise NotImplementedError

    def transform_upload(self, local: Pytree, global_params: Pytree,
                         umap: UnitMap, residual: Optional[Pytree]
                         ) -> tuple[Pytree, Optional[Pytree]]:
        return local, None

    def update_residual(self, cand_res: Pytree, old_res: Optional[Pytree],
                        sel_row: jnp.ndarray, umap: UnitMap,
                        global_params: Pytree) -> Pytree:
        raise NotImplementedError

    def aggregate(self, uploads: Pytree, umap: UnitMap,
                  selection: jnp.ndarray, data_sizes: jnp.ndarray,
                  global_params: Pytree,
                  axis_name: str | None = None) -> Pytree:
        return agg.aggregate_stacked(uploads, umap, selection, data_sizes,
                                     fallback=global_params,
                                     axis_name=axis_name)

    # ---- mesh-sharded halves of aggregate() (fused-psum protocol) ----
    def psum_parts(self, uploads: Pytree, umap: UnitMap,
                   sel_loc: jnp.ndarray, data_sizes: jnp.ndarray,
                   global_params: Optional[Pytree] = None
                   ) -> tuple[Pytree, Pytree]:
        """Additive local partials for the fused per-round psum. The
        returned ``parts`` must be param-structured; ``denom`` may be a
        single ``(U,)`` array (Eq. 5) *or* a param-structured tree of
        element-wise denominators (FedADP) — the engine slices a
        param-structured denom to 'model'-axis shards alongside ``parts``.
        ``global_params`` is the (fully gathered) global model, for
        strategies whose partials depend on it (e.g. FedADP's masks)."""
        return agg.stacked_psum_parts(uploads, umap, sel_loc, data_sizes)

    def psum_finalize(self, parts: Pytree, denom: jnp.ndarray,
                      umap: UnitMap, params_shard: Pytree,
                      fallback: Pytree) -> Pytree:
        return agg.stacked_psum_finalize(parts, denom, umap, params_shard,
                                         fallback)

    # ---- packed-uplink fast path (only when packed_upload is set) ----
    def uplink_round(self, locals_: Pytree, global_params: Pytree,
                     umap: UnitMap, selection: jnp.ndarray,
                     divs: Optional[jnp.ndarray], data_sizes: jnp.ndarray,
                     res_rows: Optional[Pytree]
                     ) -> tuple[Pytree, Optional[Pytree], dict]:
        """Single-device packed round: stacked client ``locals_`` →
        ``(new_global_params, new_residual_rows, wire)`` where ``wire`` is
        ``{"unit_bytes": (U,), "bits": (U,), "nbytes": int}`` — the packed
        payload's accounting, fed to :meth:`comm_profile` via
        ``unit_bytes_override``."""
        raise NotImplementedError(
            f"{type(self).__name__} sets packed_upload but does not "
            "implement uplink_round")

    def uplink_psum_parts(self, locals_: Pytree, global_params: Pytree,
                          umap: UnitMap, sel_loc: jnp.ndarray,
                          divs: Optional[jnp.ndarray],
                          data_sizes: jnp.ndarray,
                          res_rows: Optional[Pytree]
                          ) -> tuple[Pytree, jnp.ndarray,
                                     Optional[Pytree], dict]:
        """Mesh half of :meth:`uplink_round`: additive Eq. 5 numerator
        partials + local denominator (for the engine's fused psum, then
        :meth:`psum_finalize`), plus the local residual rows and wire
        accounting."""
        raise NotImplementedError(
            f"{type(self).__name__} sets packed_upload but does not "
            "implement uplink_psum_parts")

    # ------------------------------------------------------------------
    def comm_profile(self, selection: jnp.ndarray, umap: UnitMap,
                     param_bytes_override: float | None = None,
                     unit_bytes_override: jnp.ndarray | None = None) -> dict:
        return comm_mod.round_comm(
            selection, umap, divergence_feedback=self.needs_divergence,
            param_bytes_override=param_bytes_override,
            unit_bytes_override=unit_bytes_override)

    # ---- telemetry taps (observability; jit-safe like every hook) ----
    # global-state entries at most this many elements are passed through
    # verbatim (FedLAMA's (U,) interval/ttl vectors); larger entries are
    # summarised by their Frobenius norm instead.
    tap_passthrough_max: int = 256

    def telemetry_taps(self, state: Optional[dict],
                       selection: jnp.ndarray,
                       divs: Optional[jnp.ndarray],
                       umap: UnitMap) -> dict:
        """Per-round observability dict for the telemetry subsystem
        (``FLConfig(telemetry=TelemetryConfig(taps=True))``): a flat
        ``{name: array}`` of small summaries recorded into the round
        ledger. Called once per round inside the compiled round function
        with the same REPLICATED inputs on every engine — ``selection``
        is the (K, U) matrix, ``divs`` the (K, U) Eq. 3 divergence matrix
        (or None), and ``state`` holds only the *global* entries (client
        rows are device-local under a mesh; the engines tap their norms
        separately). Must be jit-safe with a static key set.

        Default: per-unit selection counts, per-unit divergence
        mean/max, and each global state entry — verbatim when it is a
        single small array (≤ :attr:`tap_passthrough_max` elements, e.g.
        FedLAMA's (U,) interval/ttl vectors), by norm otherwise.
        """
        taps = {"sel_count": jnp.sum(selection, axis=0)}
        if divs is not None:
            taps["div_mean"] = jnp.mean(divs, axis=0)
            taps["div_max"] = jnp.max(divs, axis=0)
        if state and state.get("global"):
            for name, entry in state["global"].items():
                leaves = jax.tree.leaves(entry)
                if len(leaves) == 1 and leaves[0].ndim <= 1 and \
                        leaves[0].size <= self.tap_passthrough_max:
                    taps[f"state_{name}"] = leaves[0]
                else:
                    sq = sum((jnp.sum(jnp.square(l.astype(jnp.float32)))
                              for l in leaves), jnp.float32(0.0))
                    taps[f"state_{name}_norm"] = jnp.sqrt(sq)
        return taps


# ======================================================================
# Registry
# ======================================================================
_REGISTRY: dict[str, type[FLStrategy]] = {}


def register_strategy(name: str, *, override: bool = False):
    """Class decorator: make ``FLConfig(algo=name)`` resolve to this
    strategy (and list it in ``ALGOS`` / the comparison bench).

    Registering a name that is already taken by a *different* class raises
    (a plugin silently replacing e.g. the ``fedavg`` baseline would corrupt
    every savings-vs-fedavg comparison with no signal); pass
    ``override=True`` to replace intentionally. Re-registering the same
    class under the same name is an idempotent no-op (module re-imports).
    """

    def deco(cls: type[FLStrategy]) -> type[FLStrategy]:
        if not (isinstance(cls, type) and issubclass(cls, FLStrategy)):
            raise TypeError(f"{cls!r} is not an FLStrategy subclass")
        existing = _REGISTRY.get(name)
        if existing is not None and existing is not cls and not override:
            raise ValueError(
                f"strategy name {name!r} is already registered to "
                f"{existing.__name__}; pass register_strategy(name, "
                "override=True) to replace it")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def unregister_strategy(name: str) -> None:
    """Remove a registered strategy (tests / plugin teardown)."""
    _REGISTRY.pop(name, None)


def registered_algos() -> tuple[str, ...]:
    """Registered algorithm names, in registration order."""
    return tuple(_REGISTRY)


def strategy_registry() -> dict[str, type[FLStrategy]]:
    return dict(_REGISTRY)


def get_strategy_cls(name: str) -> type[FLStrategy]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown FL algorithm {name!r}; registered strategies: "
            f"{', '.join(sorted(_REGISTRY)) or '(none)'}") from None
