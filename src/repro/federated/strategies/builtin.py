"""Built-in strategies: the paper's FedLDF, its baselines, and FedLP.

Each class ports one branch of the pre-refactor ``federated/server.py``
``if flcfg.algo == ...`` ladder; the engines now only see the hook surface
of :class:`~repro.federated.strategies.base.FLStrategy`. Trajectories are
bit-identical to the branch code they replace (same ops, same RNG stream —
pinned by the fixed-seed equivalence tests).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import comm as comm_mod
from repro.core import fedadp as fedadp_mod
from repro.core import selection as sel
from repro.federated.strategies.base import FLStrategy, register_strategy


# ----------------------------------------------------------------------
# Per-strategy options (``FLConfig(algo_options=...)``). Validation lives
# here, next to the knob's owner, instead of in FLConfig.__post_init__;
# the deprecated flat FLConfig fields (fedadp_keep, fedlp_p, ...) are
# folded into these by FLConfig's normalization shim.
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FedADPOptions:
    """FedADP knobs: ``keep`` — the neuron keep fraction (equal-comm
    setting vs FedLDF's n/K)."""
    keep: float = 0.2

    def __post_init__(self):
        if not 0.0 < self.keep <= 1.0:
            raise ValueError(
                f"fedadp keep fraction must be in (0, 1], got {self.keep}")


@dataclasses.dataclass(frozen=True)
class FedLPOptions:
    """FedLP knobs: ``p`` — per-layer keep probability."""
    p: float = 0.5

    def __post_init__(self):
        if not 0.0 < self.p <= 1.0:
            raise ValueError(
                f"fedlp_p must be in (0, 1], got {self.p}")


@register_strategy("fedldf")
class FedLDF(FLStrategy):
    """The paper's algorithm: top-n clients per layer-unit by divergence
    (Eq. 4), Eq. 5 aggregation, divergence-feedback uplink accounted."""

    needs_divergence = True

    def select(self, divs, key, k, u, n):
        return sel.topn_divergence(divs, n)


@register_strategy("fedavg")
class FedAvg(FLStrategy):
    """Eq. 1: full participation, everything uploaded."""

    def select(self, divs, key, k, u, n):
        return sel.full_participation(k, u)


@register_strategy("random")
class RandomPerLayer(FLStrategy):
    """Random baseline: per unit, n uniform clients upload."""

    def select(self, divs, key, k, u, n):
        return sel.random_per_layer(key, k, u, n)


@register_strategy("hdfl")
class HDFL(FLStrategy):
    """HDFL [7]: n whole clients participate, uploading all units."""

    def select(self, divs, key, k, u, n):
        return sel.client_dropout(key, k, u, n)


@register_strategy("fedadp")
class FedADP(FLStrategy):
    """FedADP [6]: per-client neuron-granularity pruning with element-wise
    masked aggregation — not an Eq. 5 selection scheme, so it overrides
    :meth:`aggregate` wholesale. Works in ``vmap`` mode, in ``scan`` mode
    (the engine stacks the sequentially-trained locals and feeds them to
    the same hook), and client-sharded over a mesh: its masked numerators
    ``Σ_k θ·m·w`` and element-wise denominators ``Σ_k m·w`` are additive
    over clients, so :meth:`psum_parts`/:meth:`psum_finalize` ride the
    engine's fused per-round psum — the denominator is a param-structured
    tree (not the Eq. 5 ``(U,)`` vector), which the engine 'model'-axis
    shards alongside the numerators on 2-D meshes."""

    options_cls = FedADPOptions
    eq5_weighted = False        # element-wise masks, not unit weights
    supports_quantize = False   # aggregates pruned neurons, not deltas

    def select(self, divs, key, k, u, n):
        # selection is accounting-only for FedADP: pruning happens at
        # neuron granularity inside aggregate()
        return sel.full_participation(k, u)

    def aggregate(self, uploads, umap, selection, data_sizes,
                  global_params, axis_name=None):
        assert axis_name is None, \
            "the mesh engine uses psum_parts/psum_finalize"
        return fedadp_mod.aggregate_fedadp(uploads, global_params,
                                           data_sizes,
                                           self.opts.keep)

    # ---- mesh halves: per-leaf additive masked partials ----
    def psum_parts(self, uploads, umap, sel_loc, data_sizes,
                   global_params=None):
        assert global_params is not None, \
            "fedadp psum_parts needs the global model for its masks"
        return fedadp_mod.fedadp_psum_parts(uploads, global_params,
                                            data_sizes,
                                            self.opts.keep)

    def psum_finalize(self, parts, denom, umap, params_shard, fallback):
        return fedadp_mod.fedadp_psum_finalize(parts, denom, fallback)

    def comm_profile(self, selection, umap, param_bytes_override=None,
                     unit_bytes_override=None):
        comm = comm_mod.round_comm(selection, umap,
                                   divergence_feedback=False)
        # overwrite with FedADP's own accounting. The payload must be
        # recomputed alongside the total, or the metrics dict goes
        # internally inconsistent (payload + feedback != total).
        comm["uplink_total"] = jnp.float32(0.0) + comm["fedavg_uplink"] \
            * self.opts.keep
        comm["uplink_payload"] = comm["uplink_total"] \
            - comm["uplink_feedback"]
        comm["savings_frac"] = 1.0 - self.opts.keep
        return comm


@register_strategy("fedlp")
class FedLP(FLStrategy):
    """FedLP (Zhu et al., arXiv:2303.06360): layer-wise probabilistic
    participation. Each client independently keeps (uploads) each
    layer-unit with probability ``FedLPOptions.p``; the server runs the
    usual Eq. 5 weighted mean over whatever arrived, falling back to the
    previous global value for units nobody kept. Expected uplink is
    ``p × FedAvg`` with zero feedback traffic — the comm profile adds only
    the per-client keep-mask header (U bits/client) the server needs to
    know which layers are present.

    Eq. 5 aggregation + replicated-key selection ⇒ full engine support:
    vmap, scan (streaming), mesh-sharded, and quantized uploads all work.
    """

    options_cls = FedLPOptions

    def select(self, divs, key, k, u, n):
        return sel.bernoulli_per_layer(key, k, u, self.opts.p)

    def comm_profile(self, selection, umap, param_bytes_override=None,
                     unit_bytes_override=None):
        stats = comm_mod.round_comm(
            selection, umap, divergence_feedback=False,
            param_bytes_override=param_bytes_override,
            unit_bytes_override=unit_bytes_override)
        # keep-mask header: U bits per participating client, byte-padded.
        # Additive in the client axis, so the sharded engine's psum over
        # local rows sums to the global header cost.
        mask_bytes = jnp.float32(selection.shape[0]
                                 * ((umap.num_units + 7) // 8))
        stats["uplink_feedback"] = stats["uplink_feedback"] + mask_bytes
        stats["uplink_total"] = stats["uplink_total"] + mask_bytes
        stats["savings_frac"] = (1.0 - stats["uplink_total"]
                                 / stats["fedavg_uplink"])
        return stats
