"""Writing your own FL algorithm as a strategy plugin.

    PYTHONPATH=src python examples/custom_strategy.py [--rounds N]

``register_strategy`` is the whole integration surface: subclass
:class:`repro.federated.FLStrategy`, implement the jit-safe hooks your
scheme needs (here just ``select`` — aggregation, comm accounting, scan
streaming, mesh sharding, and quantized uploads are all inherited from
the Eq. 5 base), decorate the class, and ``FLConfig(algo=<name>)`` plus
every engine, the ``ALGOS`` listing, and ``benchmarks/fl_comparison.py``
pick it up automatically.

The demo scheme, "softmax-divergence", is a stochastic softening of the
paper's Eq. 4: instead of deterministically taking the top-n clients per
layer, it samples n clients per layer with probability ∝ softmax of the
divergence scores — same n/K uplink, but cold clients still occasionally
contribute. (This is a demo of the plugin seam, not a claim that it beats
FedLDF.)
"""
import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.federated as fed
from repro.core.selection import topn_divergence
from repro.data import FederatedData, iid_partition, make_image_dataset
from repro.federated import (FLConfig, FLStrategy, register_strategy,
                             run_training_scan)
from repro.models import cnn


@register_strategy("softmax-div")
class SoftmaxDivergence(FLStrategy):
    """Sample n clients per layer ∝ softmax(divergence / temperature)."""

    needs_divergence = True   # the engine feeds us the (K, U) Eq. 3 matrix

    TEMPERATURE = 0.05

    def select(self, divs, key, k, u, n):
        # Gumbel-top-n per unit = sampling n clients without replacement
        # with probability ∝ softmax(divs / T). Every op is jit-safe and
        # deterministic in `key`, so all engines (vmap/scan/mesh) agree.
        gumbel = -jnp.log(-jnp.log(
            jax.random.uniform(key, divs.shape, minval=1e-9, maxval=1.0)))
        scores = divs / self.TEMPERATURE + gumbel
        return topn_divergence(scores, n)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()

    print("registered algorithms:", ", ".join(fed.ALGOS))
    assert "softmax-div" in fed.ALGOS

    cfg = cnn.VGGConfig().reduced()
    train, _ = make_image_dataset(num_train=500, num_test=16, seed=0)
    data = FederatedData(train.xs, train.ys,
                         iid_partition(train.ys, 10, seed=0))
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)
    loss_fn = functools.partial(lambda c, p, b: cnn.classify_loss(p, c, b),
                                cfg)

    # the custom name drops straight into FLConfig — validation, the
    # device-resident scan engine, comm accounting, everything applies
    fl = FLConfig(algo="softmax-div", num_clients=10, clients_per_round=5,
                  top_n=2, lr=0.05, batch_per_client=8)
    params, log = run_training_scan(params, loss_fn, data, fl,
                                    rounds=args.rounds, seed=0)
    assert all(np.isfinite(l) for l in log.losses)
    print(f"losses: {[f'{l:.3f}' for l in log.losses]}")
    print(f"uplink {log.meter.uplink_bytes/1e6:.2f} MB over "
          f"{log.meter.rounds} rounds "
          f"({log.meter.savings_frac*100:.1f}% saved vs FedAvg)")


if __name__ == "__main__":
    main()
