"""Writing your own FL algorithm as a strategy plugin.

    PYTHONPATH=src python examples/custom_strategy.py [--rounds N]

``register_strategy`` is the whole integration surface: subclass
:class:`repro.federated.FLStrategy`, implement the jit-safe hooks your
scheme needs (here just ``select`` — aggregation, comm accounting, scan
streaming, mesh sharding, and quantized uploads are all inherited from
the Eq. 5 base), decorate the class, and ``FLConfig(algo=<name>)`` plus
every engine, the ``ALGOS`` listing, and ``benchmarks/fl_comparison.py``
pick it up automatically.

The demo scheme, "softmax-divergence", is a stochastic softening of the
paper's Eq. 4: instead of deterministically taking the top-n clients per
layer, it samples n clients per layer with probability ∝ softmax of the
divergence scores — same n/K uplink, but cold clients still occasionally
contribute. (This is a demo of the plugin seam, not a claim that it beats
FedLDF.)

The second scheme, "softmax-div-annealed", demonstrates the **cross-round
state seam**: declare per-run state once in ``init_state`` (return None —
the default — and the engines add zero carry leaves), read it in
``select_with_state``, advance it in ``update_state``. All three drivers
(host vmap loop, jitted scan, mesh-sharded) thread the state for you, and
``save_server_state``/``load_server_state`` checkpoint it alongside the
params. Here the state is a single round counter that anneals the sampling
temperature from exploration toward the paper's deterministic Eq. 4.
"""
import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.federated as fed
from repro.core.selection import topn_divergence
from repro.data import FederatedData, iid_partition, make_image_dataset
from repro.federated import (FLConfig, FLStrategy, register_strategy,
                             run_training_scan)
from repro.models import cnn


@register_strategy("softmax-div")
class SoftmaxDivergence(FLStrategy):
    """Sample n clients per layer ∝ softmax(divergence / temperature)."""

    needs_divergence = True   # the engine feeds us the (K, U) Eq. 3 matrix

    TEMPERATURE = 0.05

    def select(self, divs, key, k, u, n):
        # Gumbel-top-n per unit = sampling n clients without replacement
        # with probability ∝ softmax(divs / T). Every op is jit-safe and
        # deterministic in `key`, so all engines (vmap/scan/mesh) agree.
        return self._select_at_temperature(divs, key, n, self.TEMPERATURE)

    @staticmethod
    def _select_at_temperature(divs, key, n, temperature):
        gumbel = -jnp.log(-jnp.log(
            jax.random.uniform(key, divs.shape, minval=1e-9, maxval=1.0)))
        scores = divs / temperature + gumbel
        return topn_divergence(scores, n)


@register_strategy("softmax-div-annealed")
class AnnealedSoftmaxDivergence(SoftmaxDivergence):
    """Stateful variant: a cross-round counter anneals the temperature, so
    early rounds explore (≈ uniform sampling) and late rounds converge on
    the paper's deterministic top-n. The three hooks below are the entire
    stateful surface — every engine threads the state automatically."""

    ANNEAL = 1.5   # temperature multiplier per round (T grows ⇒ sharper)

    def init_state(self, params, num_clients, mesh=None):
        # "global" entries are replicated trees updated wholesale each
        # round; "client" entries (not needed here) carry a leading
        # (num_clients,) axis and get per-participant row gather/scatter.
        return {"global": {"round": jnp.float32(0.0)}}

    def select_with_state(self, state, divs, key, k, u, n):
        t = state["global"]["round"]
        # sharper softmax every round: T_t = T0 / ANNEAL^t
        temperature = self.TEMPERATURE / jnp.power(self.ANNEAL, t)
        return self._select_at_temperature(divs, key, n, temperature)

    def update_state(self, state, selection, divs, umap, key=None):
        # jit-safe, shape-preserving transition — runs once per round,
        # after aggregation, in every driver.
        return {"global": {"round": state["global"]["round"] + 1.0}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()

    print("registered algorithms:", ", ".join(fed.ALGOS))
    assert "softmax-div" in fed.ALGOS

    cfg = cnn.VGGConfig().reduced()
    train, _ = make_image_dataset(num_train=500, num_test=16, seed=0)
    data = FederatedData(train.xs, train.ys,
                         iid_partition(train.ys, 10, seed=0))
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)
    loss_fn = functools.partial(lambda c, p, b: cnn.classify_loss(p, c, b),
                                cfg)

    # the custom name drops straight into FLConfig — validation, the
    # device-resident scan engine, comm accounting, everything applies
    fl = FLConfig(algo="softmax-div", num_clients=10, clients_per_round=5,
                  top_n=2, lr=0.05, batch_per_client=8)
    params, log = run_training_scan(params, loss_fn, data, fl,
                                    rounds=args.rounds, seed=0)
    assert all(np.isfinite(l) for l in log.losses)
    print(f"losses: {[f'{l:.3f}' for l in log.losses]}")
    print(f"uplink {log.meter.uplink_bytes/1e6:.2f} MB over "
          f"{log.meter.rounds} rounds "
          f"({log.meter.savings_frac*100:.1f}% saved vs FedAvg)")

    # --- the stateful variant: same engine, plus a cross-round carry ---
    fl2 = FLConfig(algo="softmax-div-annealed", num_clients=10,
                   clients_per_round=5, top_n=2, lr=0.05,
                   batch_per_client=8)
    p0 = cnn.init_params(jax.random.PRNGKey(0), cfg)
    _, log2 = run_training_scan(p0, loss_fn, data, fl2,
                                rounds=args.rounds, seed=0)
    assert all(np.isfinite(l) for l in log2.losses)
    # the engine hands the final strategy state back on the log
    rounds_seen = float(log2.final_state["global"]["round"])
    assert rounds_seen == args.rounds, rounds_seen
    print(f"annealed variant: state counted {rounds_seen:.0f} rounds, "
          f"uplink {log2.meter.uplink_bytes/1e6:.2f} MB "
          f"({log2.meter.savings_frac*100:.1f}% saved vs FedAvg)")


if __name__ == "__main__":
    main()
