"""Beyond-paper example: FedLDF + quantized-delta uploads + error feedback.

Composes the paper's layer selection (n/K uplink) with int-b delta
quantization (b/32) and client-side error feedback — e.g. n/K=0.2 × int8
⇒ ~97.5 % total uplink reduction vs FedAvg.

    PYTHONPATH=src python examples/compressed_fl.py --bits 8 --rounds 20

``--bits auto`` turns on divergence-driven per-layer bit allocation: the
packed wire format waterfills widths in [2, 8] (4-bit average budget)
from the round's Eq. 3 divergence stats, so fast-diverging layers get
finer quantization under the same byte budget.
"""
import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.units import UnitMap
from repro.data import FederatedData, dirichlet_partition, make_image_dataset
from repro.federated import (CompressionConfig, FLConfig, build_round_fn,
                             sample_clients)
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", default="8",
                    help="quantization width 2..8, or 'auto' for "
                         "divergence-driven per-layer allocation")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--no-error-feedback", action="store_true")
    args = ap.parse_args()
    bits = args.bits if args.bits == "auto" else int(args.bits)

    cfg = cnn.VGGConfig().reduced()
    n_clients, k, n = 12, 6, 2
    train, test = make_image_dataset(num_train=2400, num_test=480, seed=0)
    parts = dirichlet_partition(train.ys, n_clients, alpha=1.0, seed=0)
    data = FederatedData(train.xs, train.ys, parts)
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)
    umap = UnitMap.build(params)
    loss_fn = functools.partial(lambda c, p, b: cnn.classify_loss(p, c, b),
                                cfg)
    test_batch = {"images": jnp.asarray(test.xs),
                  "labels": jnp.asarray(test.ys)}
    eval_fn = jax.jit(lambda p: 1.0 - cnn.accuracy(p, cfg, test_batch))

    use_ef = not args.no_error_feedback
    fl = FLConfig(algo="fedldf", num_clients=n_clients, clients_per_round=k,
                  top_n=n, lr=0.08, mode="vmap", batch_per_client=16,
                  compression=CompressionConfig(bits=bits,
                                                error_feedback=use_ef))
    round_fn = jax.jit(build_round_fn(loss_fn, umap, fl))

    # error-feedback residuals live per client (host-side store, all N).
    # Since the cross-round state seam, they are strategy state: the
    # quantize wrapper declares a client entry named "residual", and a
    # round_fn takes the ROUND-LOCAL state view — client entries hold the
    # round's participant rows (K, ...) — returning the updated view in
    # metrics["state"]. (The run_training* drivers do this gather/scatter
    # for you; this example hand-rolls the loop to show the seam.)
    zero_res = jax.tree.map(lambda l: jnp.zeros_like(l, jnp.float32), params)
    residuals = {i: zero_res for i in range(n_clients)} if use_ef else None

    rng = np.random.default_rng(0)
    sizes_all = data.data_sizes()
    uplink = fedavg_ref = 0.0
    for t in range(args.rounds):
        clients = sample_clients(rng, n_clients, k)
        batch = {kk: jnp.asarray(v) for kk, v in
                 data.round_batch(clients, fl.batch_per_client, rng).items()}
        sizes = jnp.asarray(sizes_all[clients])
        key = jax.random.PRNGKey(t)
        if use_ef:
            res_in = jax.tree.map(lambda *ls: jnp.stack(ls),
                                  *[residuals[int(c)] for c in clients])
            state_in = {"client": {"residual": res_in}}
            new_p, metrics = round_fn(params, batch, sizes, key, state_in)
            res_out = metrics["state"]["client"]["residual"]
            for i, c in enumerate(clients):
                residuals[int(c)] = jax.tree.map(lambda l: l[i], res_out)
        else:
            new_p, metrics = round_fn(params, batch, sizes, key)
        params = new_p
        uplink += float(metrics["comm"]["uplink_total"])
        fedavg_ref += float(metrics["comm"]["fedavg_uplink"])
        if t % 5 == 0 or t == args.rounds - 1:
            print(f"round {t:3d} loss {float(metrics['loss']):.4f} "
                  f"err {float(eval_fn(params)):.4f} "
                  f"uplink {uplink/1e6:7.2f}MB "
                  f"(saved {100*(1-uplink/fedavg_ref):.1f}% vs FedAvg)")
    print(f"\n{'auto-bit' if bits == 'auto' else f'int{bits}'} "
          f"+ top-{n}/{k} selection + "
          f"{'EF' if use_ef else 'no EF'}: "
          f"total uplink saving {100*(1-uplink/fedavg_ref):.2f}%")


if __name__ == "__main__":
    main()
