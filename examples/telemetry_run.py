"""Round telemetry walkthrough: taps -> JSONL ledger -> terminal monitor.

    PYTHONPATH=src python examples/telemetry_run.py [--rounds N]
        [--ledger PATH]

Runs a small synthetic-CIFAR federated task under
``FLConfig(telemetry=TelemetryConfig(...))`` for three strategies
(fedldf, fedlama, fedlp) on both multi-round drivers (the host loop and
the jitted scan engine), plus one FedLDF run sharded over a 2-D
('clients' x 'model') device mesh — all appending run segments into ONE
JSONL event ledger. It then renders every segment with the terminal
monitor (``repro.launch.monitor``): per-layer divergence and selection
heat tables, strategy-state trajectories (FedLAMA's adapted intervals),
and the bytes/savings/loss summary.

The ledger is append-mode and schema-versioned, so the same file can be
tailed live, re-rendered later on a machine without JAX, or continued by
a resumed run (``start_round``/``server_state``) without losing history.
"""
import argparse
import os
import tempfile

# a 4-device CPU "cluster", forced before jax import so the mesh run is
# real (2 client shards x 2 model shards), same as REPRO_TEST_DEVICES=4
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax                                                     # noqa: E402
import numpy as np                                             # noqa: E402

from repro.data import (FederatedData, iid_partition,          # noqa: E402
                        make_image_dataset)
from repro.federated import (FLConfig, TelemetryConfig,        # noqa: E402
                             run_training, run_training_scan)
from repro.launch import monitor                               # noqa: E402
from repro.launch.mesh import make_client_mesh                 # noqa: E402
from repro.models import cnn                                   # noqa: E402

N_CLIENTS, K = 10, 5


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--ledger", default=None,
                    help="ledger path (default: a temp file)")
    args = ap.parse_args()

    ledger = args.ledger or os.path.join(
        tempfile.mkdtemp(prefix="telemetry_run_"), "ledger.jsonl")

    cfg = cnn.VGGConfig().reduced()
    train, _ = make_image_dataset(num_train=400, num_test=16, seed=0)
    data = FederatedData(train.xs, train.ys,
                         iid_partition(train.ys, N_CLIENTS, seed=0))
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, b):
        return cnn.classify_loss(p, cfg, b)

    def fl(algo, clients_per_round=K, **kw):
        return FLConfig(algo=algo, num_clients=N_CLIENTS,
                        clients_per_round=clients_per_round, top_n=2,
                        lr=0.05, batch_per_client=8, **kw)

    def tele(run_id):
        # full_selection=False keeps the records lean for this demo; the
        # per-layer taps (divergence, sel_count, state_*) stay on
        return TelemetryConfig(ledger_path=ledger, run_id=run_id,
                               full_selection=False)

    # ---- three strategies x two drivers, one ledger ----
    for algo in ("fedldf", "fedlama", "fedlp"):
        p, log = run_training(params, loss_fn, data,
                              fl(algo, telemetry=tele(f"{algo}/host")),
                              rounds=args.rounds, seed=0, sampler="jax")
        assert all(np.isfinite(l) for l in log.losses)
        p, log = run_training_scan(params, loss_fn, data,
                                   fl(algo, telemetry=tele(f"{algo}/scan")),
                                   rounds=args.rounds, seed=0)
        assert all(np.isfinite(l) for l in log.losses)

    # ---- FedLDF over a 2-D mesh: clients sharded 2-way, params/residual
    # FSDP-sharded 2-way along 'model' ----
    mesh = make_client_mesh(4, model=2)
    run_training(params, loss_fn, data,
                 fl("fedldf", clients_per_round=4, mesh=mesh,
                    telemetry=tele("fedldf/mesh2x2")),
                 rounds=args.rounds, seed=0, sampler="jax")

    # ---- render everything the runs ledgered ----
    print(f"\n=== {ledger} ===")
    n = monitor.render(ledger, bins=40)
    print(f"\n{n} run segments rendered from {ledger}")
    assert n == 7, n   # 3 algos x 2 drivers + the mesh run


if __name__ == "__main__":
    main()
