"""Federated LLM fine-tuning: adapter-only uplink over a frozen base.

The trainable-partition seam (``FLConfig(partition=...)``) plus LoRA
adapters (``repro.models.lora``) turn the FL engine into a federated
fine-tuning engine: the base transformer is broadcast once and stays
device-resident, clients train and upload only the low-rank factors, and
FedLDF's Eq. 3 divergence scores per-depth *adapter* units. Composes with
the packed quantized wire (``CompressionConfig``) for a further cut.

    PYTHONPATH=src python examples/fl_finetune_llm.py --rounds 2

Prints a comm table comparing each algorithm's adapter uplink against the
full-model FedAvg upload of the same transformer.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core.partition import partition_counts
from repro.data import lm_federated, make_lm_dataset
from repro.federated import CompressionConfig, FLConfig, run_training
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.lora import inject_lora, lora_partition


def tiny_lm() -> ModelConfig:
    """A 4-layer toy LM — the workload shape, not the workload size."""
    return ModelConfig(name="tiny-lm", family="dense", d_model=64,
                       num_layers=4, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=256, param_dtype="float32",
                       compute_dtype="float32")


def _tree_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--rank", type=int, default=2)
    ap.add_argument("--top-n", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.3)
    args = ap.parse_args()

    cfg = tiny_lm()
    n_clients, k = 8, 4
    tokens, domains = make_lm_dataset(num_sequences=320, seq_len=33,
                                      vocab=cfg.vocab_size, num_domains=8,
                                      seed=0)
    data = lm_federated(tokens[:256], domains[:256], n_clients)
    eval_batch = {"tokens": jnp.asarray(tokens[256:, :-1]),
                  "labels": jnp.asarray(tokens[256:, 1:])}

    base = tfm.init_params(jax.random.PRNGKey(0), cfg)
    params = inject_lora(jax.random.PRNGKey(1), base, rank=args.rank)
    part = lora_partition(params)
    counts = partition_counts(part, params)
    loss_fn = tfm.make_lm_loss(cfg)
    eval_fn = jax.jit(lambda p: tfm.lm_loss(p, cfg, eval_batch))

    full_up = _tree_bytes(params) * k       # full-model FedAvg, per round
    print(f"model: {cfg.name}  trainable {counts['trainable_params']:,} / "
          f"frozen {counts['frozen_params']:,} params "
          f"({100 * counts['trainable_bytes'] / _tree_bytes(params):.1f}% "
          f"of bytes)\n")

    runs = [
        ("fedavg_lora", dict(algo="fedavg")),
        ("fedlp_lora", dict(algo="fedlp", top_n=args.top_n, fedlp_p=0.5)),
        ("fedldf_lora", dict(algo="fedldf", top_n=args.top_n)),
        ("fedldf_lora_auto", dict(algo="fedldf", top_n=args.top_n,
                                  compression=CompressionConfig(
                                      bits="auto"))),
    ]
    rows = []
    for name, kw in runs:
        fl = FLConfig(num_clients=n_clients, clients_per_round=k,
                      lr=args.lr, batch_per_client=8, partition=part, **kw)
        trained, log = run_training(params, loss_fn, data, fl,
                                    rounds=args.rounds, eval_fn=eval_fn,
                                    eval_every=max(1, args.rounds // 3),
                                    seed=0, sampler="jax")
        up = log.meter.uplink_bytes / args.rounds
        rows.append((name, up, full_up / up, float(eval_fn(trained))))
        print(f"  {name:<18s} done; final eval loss {rows[-1][3]:.4f}")

    print(f"\n{'algo':<18s} {'uplink/round':>14s} {'vs full FedAvg':>15s} "
          f"{'eval loss':>10s}")
    print(f"{'fedavg_full':<18s} {full_up / 1e3:>12.1f}kB {'1.0x':>15s} "
          f"{'-':>10s}")
    for name, up, ratio, ev in rows:
        print(f"{name:<18s} {up / 1e3:>12.1f}kB {ratio:>14.1f}x "
              f"{ev:>10.4f}")
    best = max(r[2] for r in rows)
    print(f"\nadapter-only uplink: {best:.0f}x below full-model upload "
          f"(frozen base never travels the wire)")


if __name__ == "__main__":
    main()
