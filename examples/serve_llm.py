"""Serve a FedLDF-trained LLM: federated fine-tune (scan mode, the
large-model path) then batched autoregressive decoding with the KV cache.

    PYTHONPATH=src python examples/serve_llm.py --arch mamba2-780m --rounds 3
"""
import argparse
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data import lm_federated, make_lm_dataset
from repro.federated import FLConfig, run_training
from repro.models import decode as dec
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2-780m")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--steps", type=int, default=12)
    args = ap.parse_args()

    # reduced variant: same family wiring, CPU-sized
    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              param_dtype="float32",
                              compute_dtype="float32")
    print(f"arch={args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model})")

    # --- federated fine-tuning on non-IID domain data (scan mode) ------
    toks, domains = make_lm_dataset(num_sequences=128, seq_len=48,
                                    vocab=cfg.vocab_size, seed=0)
    data = lm_federated(toks, domains, num_clients=6)
    fl = FLConfig(algo="fedldf", num_clients=6, clients_per_round=3,
                  top_n=1, lr=0.05, mode="scan", batch_per_client=4)
    loss_fn = functools.partial(lambda c, p, b: tf.lm_loss(p, c, b), cfg)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    params, log = run_training(params, loss_fn, data, fl, rounds=args.rounds,
                               seed=0, verbose=True)
    print("uplink saved vs FedAvg:", f"{log.meter.savings_frac*100:.1f}%")

    # --- serve the aggregated global model ------------------------------
    prompts = jnp.asarray(toks[:4, :16].astype(np.int32))
    if cfg.is_encdec:
        enc = jax.random.normal(jax.random.PRNGKey(1),
                                (4, 16, cfg.frontend_dim))
        logits, cache = dec.prefill(params, cfg, prompts, enc_inputs=enc,
                                    max_len=16 + args.steps)
    else:
        logits, cache = dec.prefill(params, cfg, prompts,
                                    max_len=16 + args.steps)
    out = [jnp.argmax(logits, -1)[:, None]]
    for _ in range(args.steps - 1):
        logits, cache = dec.decode_step(params, cfg, out[-1], cache)
        out.append(jnp.argmax(logits, -1)[:, None])
    gen = np.asarray(jnp.concatenate(out, axis=1))
    for i in range(2):
        print(f"prompt {prompts[i, :8].tolist()} -> gen {gen[i].tolist()}")


if __name__ == "__main__":
    main()
