"""FedLAMA: layer-wise adaptive aggregation intervals (arXiv:2110.10302).

    PYTHONPATH=src python examples/fedlama_fl.py [--rounds N] [--tau T]
        [--lam L]

The first genuinely *stateful* strategy in the registry, and the proof
workload of the cross-round state seam: FedLAMA keeps three replicated
(U,) vectors in strategy state — per-layer-unit ``ttl`` (rounds until the
next synchronisation), ``interval`` (τ_u ∈ {τ', λτ'}), and ``disc`` (the
discrepancy estimate that drives the interval assignment). Low-drift
layers are synchronised every λτ' rounds instead of every τ', so uplink
drops well below FedAvg while high-drift layers stay fresh.

This example runs the jitted scan engine on the synthetic CIFAR-10-like
task, prints the adapted interval distribution, then checkpoints mid-run
with ``save_server_state`` (params + strategy state in one npz) and
resumes with ``start_round``/``server_state`` to show the continuation is
bit-identical to the uninterrupted run.
"""
import argparse
import functools
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_server_state, save_server_state
from repro.data import FederatedData, iid_partition, make_image_dataset
from repro.federated import FedLAMAOptions, FLConfig, run_training_scan
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--tau", type=int, default=2,
                    help="base aggregation interval τ'")
    ap.add_argument("--lam", type=int, default=2,
                    help="interval stretch λ for low-discrepancy layers")
    args = ap.parse_args()

    cfg = cnn.VGGConfig().reduced()
    train, _ = make_image_dataset(num_train=500, num_test=16, seed=0)
    data = FederatedData(train.xs, train.ys,
                         iid_partition(train.ys, 10, seed=0))
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)
    loss_fn = functools.partial(lambda c, p, b: cnn.classify_loss(p, c, b),
                                cfg)

    fl = FLConfig(algo="fedlama", num_clients=10, clients_per_round=5,
                  top_n=2, lr=0.05, batch_per_client=8,
                  algo_options=FedLAMAOptions(tau=args.tau, lam=args.lam))
    p_full, log = run_training_scan(params, loss_fn, data, fl,
                                    rounds=args.rounds, seed=0)
    assert all(np.isfinite(l) for l in log.losses)

    g = log.final_state["global"]
    intervals = np.asarray(g["interval"])
    base, long_ = float(args.tau), float(args.tau * args.lam)
    print(f"losses: {[f'{l:.3f}' for l in log.losses]}")
    print(f"adapted intervals: {int((intervals == base).sum())} units @ "
          f"τ'={base:.0f}, {int((intervals == long_).sum())} units @ "
          f"λτ'={long_:.0f}")
    print(f"uplink {log.meter.uplink_bytes/1e6:.2f} MB over "
          f"{log.meter.rounds} rounds "
          f"({log.meter.savings_frac*100:.1f}% saved vs FedAvg)")

    # --- checkpoint the stateful run mid-way and resume it ---
    half = args.rounds // 2
    p_half, l_half = run_training_scan(params, loss_fn, data, fl,
                                       rounds=half, seed=0)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "server.npz")
        save_server_state(path, p_half, l_half.final_state)
        p_loaded, state_loaded = load_server_state(path)
    p_res, _ = run_training_scan(p_loaded, loss_fn, data, fl,
                                 rounds=args.rounds - half, seed=0,
                                 start_round=half,
                                 server_state=state_loaded)
    drift = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)))
    assert drift == 0.0, f"resume drifted from uninterrupted run: {drift}"
    print(f"save → load → resume at round {half}: bit-identical to the "
          f"uninterrupted {args.rounds}-round run")


if __name__ == "__main__":
    main()
