"""Quickstart: one FedLDF round step by step, then a scanned training run.

    PYTHONPATH=src python examples/quickstart.py [--rounds N]

Walks the paper's Algorithm 1 with the public API: local training (Eq. 2),
per-layer divergence (Eq. 3), top-n selection (Eq. 4), layer-wise
aggregation (Eq. 5/6), and the communication ledger — then hands the same
model to ``run_training_scan``, which runs the whole multi-round schedule
as one jitted ``lax.scan`` on device.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import (UnitMap, aggregate_stacked, round_comm,
                        topn_divergence)
from repro.data import FederatedData, iid_partition, make_image_dataset
from repro.federated import FLConfig, make_local_update, run_training_scan
from repro.models import cnn
from repro.optim import sgd

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=10,
                help="rounds for the multi-round scan-engine demo")
args = ap.parse_args()

# --- setup: a small CNN and K=5 clients --------------------------------
cfg = cnn.VGGConfig().reduced()
global_params = cnn.init_params(jax.random.PRNGKey(0), cfg)
umap = UnitMap.build(global_params)
print(f"model: {cfg.name}, L={umap.num_units} layer-units "
      f"({umap.total_params/1e3:.0f}k params)")
print("units:", umap.names)

K, N_TOP = 5, 2
key = jax.random.PRNGKey(1)
batch = {
    "images": jax.random.normal(key, (K, 8, 32, 32, 3)),
    "labels": jax.random.randint(key, (K, 8), 0, cfg.num_classes),
}
data_sizes = jnp.array([100.0, 150.0, 80.0, 120.0, 100.0])  # |D_k|

# --- Step 1-2: broadcast + local training (Eq. 2) ----------------------
local_update = make_local_update(
    lambda p, b: cnn.classify_loss(p, cfg, b), sgd(0.05), local_steps=1)
locals_, losses = jax.vmap(local_update, in_axes=(None, 0))(
    global_params, batch)
print(f"\nlocal losses: {[f'{l:.3f}' for l in losses.tolist()]}")

# --- Step 3: divergence feedback (Eq. 3) — K·L scalars uplink ----------
divs = jax.vmap(lambda p: umap.divergence(p, global_params))(locals_)
print(f"divergence matrix (K×U):\n{jnp.round(divs, 4)}")

# --- Step 4: top-n per layer (Eq. 4) -----------------------------------
selection = topn_divergence(divs, N_TOP)
print(f"selection (exactly n={N_TOP} per column):\n{selection.astype(int)}")

# --- Step 5: layer-wise aggregation (Eq. 5/6) --------------------------
new_global = aggregate_stacked(locals_, umap, selection, data_sizes,
                               fallback=global_params)

# --- the point of it all: the communication ledger ---------------------
comm = round_comm(selection, umap)
print(f"\nuplink: {float(comm['uplink_total'])/1e3:.1f} kB "
      f"(FedAvg would be {float(comm['fedavg_uplink'])/1e3:.1f} kB) "
      f"-> {float(comm['savings_frac'])*100:.1f}% saved")
print("done — new global model ready for the next round.")

# --- multi-round: the device-resident scan engine ----------------------
# run_training_scan lifts the whole schedule (sampling, batch gathering,
# local training, selection, aggregation, comm accounting) into one jitted
# lax.scan over rounds — no per-round host work at all.
print(f"\n--- {args.rounds} rounds with run_training_scan ---")
train, _ = make_image_dataset(num_train=500, num_test=16, seed=2)
data = FederatedData(train.xs, train.ys, iid_partition(train.ys, 10, seed=0))
flcfg = FLConfig(algo="fedldf", num_clients=10, clients_per_round=K,
                 top_n=N_TOP, lr=0.05, mode="vmap", batch_per_client=8)
final_params, log = run_training_scan(new_global, lambda p, b:
                                      cnn.classify_loss(p, cfg, b),
                                      data, flcfg, rounds=args.rounds,
                                      seed=0)
print(f"losses: {[f'{l:.3f}' for l in log.losses]}")
print(f"total uplink {log.meter.uplink_bytes/1e6:.2f} MB over "
      f"{log.meter.rounds} rounds "
      f"({log.meter.savings_frac*100:.1f}% saved vs FedAvg)")
