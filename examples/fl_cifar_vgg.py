"""End-to-end driver: the paper's experiment (§III) at configurable scale.

Trains VGG on the synthetic CIFAR-10-like task with FedLDF and the FedAvg /
Random / HDFL / FedADP baselines, IID or Dirichlet(α=1), and reports the
error-vs-communication trade-off (paper Figs. 3-4) plus the Theorem-1 bound
for the same (n, K).

    PYTHONPATH=src python examples/fl_cifar_vgg.py --rounds 60
    PYTHONPATH=src python examples/fl_cifar_vgg.py --paper-scale --rounds 1000
"""
import argparse
import functools

import jax
import jax.numpy as jnp

from repro.core.convergence import BoundParams, asymptotic_gap
from repro.data import (FederatedData, dirichlet_partition, iid_partition,
                        make_image_dataset)
from repro.federated import FedADPOptions, FLConfig, run_training
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--algos", default="fedldf,fedavg,random")
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.paper_scale:
        cfg, n_clients, k, n = cnn.VGGConfig(), 50, 20, 4
        n_train, n_test, batch = 50_000, 10_000, 32
    else:
        cfg, n_clients, k, n = cnn.VGGConfig().reduced(), 20, 10, 2
        n_train, n_test, batch = 4_000, 800, 16

    train, test = make_image_dataset(num_train=n_train, num_test=n_test,
                                     seed=args.seed)
    split = (functools.partial(dirichlet_partition, alpha=1.0)
             if args.non_iid else iid_partition)
    parts = split(train.ys, n_clients, seed=args.seed)
    data = FederatedData(train.xs, train.ys, parts)
    test_batch = {"images": jnp.asarray(test.xs),
                  "labels": jnp.asarray(test.ys)}
    loss_fn = functools.partial(lambda c, p, b: cnn.classify_loss(p, c, b),
                                cfg)
    eval_fn = jax.jit(lambda p: 1.0 - cnn.accuracy(p, cfg, test_batch))

    print(f"setting: {'paper' if args.paper_scale else 'reduced'} "
          f"N={n_clients} K={k} n={n} "
          f"{'Dirichlet(1)' if args.non_iid else 'IID'}")
    final = {}
    for algo in args.algos.split(","):
        fl = FLConfig(algo=algo, num_clients=n_clients, clients_per_round=k,
                      top_n=n, lr=0.08, mode="vmap", batch_per_client=batch,
                      algo_options=(FedADPOptions(keep=n / k)
                                    if algo == "fedadp" else None))
        params = cnn.init_params(jax.random.PRNGKey(args.seed), cfg)
        params, log = run_training(params, loss_fn, data, fl,
                                   rounds=args.rounds, eval_fn=eval_fn,
                                   eval_every=max(1, args.rounds // 8),
                                   seed=args.seed, verbose=False)
        err = log.test_errors[-1][1]
        up = log.meter.uplink_bytes / 1e6
        final[algo] = (err, up)
        print(f"  {algo:8s} final_err={err:.4f} uplink={up:9.1f}MB "
              f"savings={log.meter.savings_frac*100:5.1f}%")

    if "fedldf" in final and "fedavg" in final:
        e1, u1 = final["fedldf"]
        e2, u2 = final["fedavg"]
        print(f"\nFedLDF vs FedAvg: Δerr={e1-e2:+.4f} at "
              f"{(1-u1/u2)*100:.0f}% less uplink (paper: ≈equal error, 80%)")
    bound = asymptotic_gap(BoundParams(
        beta=1.0, xi1=0.05, xi2=0.02, grad_bound=1.0, eta=0.05,
        num_layers=cfg.num_layers, n=n, k=k))
    print(f"Theorem-1 asymptotic gap bound for (n={n}, K={k}): {bound:.4f}")


if __name__ == "__main__":
    main()
